//! Migration wire protocol (paper §3.3).
//!
//! A remotable step is *packaged* for the wire as its XAML subtree
//! ("task code") plus the values of its input variables; application
//! data does **not** ride in the request — it is referenced by MDSS
//! URI (paper §3.4) and moved separately, only when stale. Responses
//! carry the written variable values, the remote simulated time, and
//! any cloud-side WriteLine output.
//!
//! Encoding: JSON (jsonmini) with the step subtree embedded as XML
//! text, so the exact developer-visible step definition round-trips
//! ("packaged as before and shipped back").
//!
//! Service mode adds *run-lifecycle* messages on the same signed
//! wire: [`RunRequest`] (submit / status / cancel, see
//! [`crate::service`]) and its [`RunReply`].
//!
//! ```
//! use std::collections::BTreeMap;
//! use emerald::migration::protocol::{OffloadRequest, RunOp, RunRequest};
//! use emerald::migration::security::SigningKey;
//! use emerald::workflow::{Step, StepKind};
//!
//! // Package a step, sign it, and round-trip it over the wire.
//! let step = Step::new(
//!     "double",
//!     StepKind::InvokeActivity {
//!         activity: "math.double".into(),
//!         inputs: vec![("x".into(), "x".into())],
//!         outputs: vec![("y".into(), "y".into())],
//!     },
//! );
//! let key = SigningKey::new(b"secret".to_vec());
//! let mut req = OffloadRequest::package(&step, BTreeMap::new(), &["y".to_string()]);
//! req.sign(&key);
//! let back = OffloadRequest::decode(&req.encode())?;
//! assert!(back.verify(&key));
//! assert_eq!(back.step()?.display_name, "double");
//!
//! // Run-lifecycle messages ride the same signed wire.
//! let mut sub = RunRequest::new(RunOp::Submit {
//!     tenant: "alice".into(),
//!     workflow_xml: "<Workflow/>".into(),
//! });
//! sub.sign(&key);
//! assert!(RunRequest::decode(&sub.encode())?.verify(&key));
//! # Ok::<(), anyhow::Error>(())
//! ```

use std::collections::BTreeMap;

use anyhow::{bail, Context, Result};

use crate::expr::Value;
use crate::jsonmini::{self, Value as J};
use crate::workflow::{xaml, Step, StepKind};

/// Placement pin: the cloud VM the scheduler leased for this offload.
/// Both the index and the speed travel so the worker executes on
/// exactly the node the scheduler chose even when its own platform
/// config differs — this is what keeps placement and execution from
/// diverging on heterogeneous pools.
///
/// A lease the work-stealing pass re-pinned
/// ([`crate::scheduler::Lease::try_steal`]) travels through this same
/// field: the manager steals *before* packaging, so the pin always
/// names the VM that will actually execute, signatures cover the final
/// placement, and the wire format is unchanged (peers without the
/// field still decode, prices never cross the wire).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct PinnedNode {
    /// Global cloud-node index (tier order; see
    /// [`crate::cloud::Platform::cloud_node_at`]).
    pub index: usize,
    /// Speed factor of the leased VM.
    pub speed: f64,
}

/// Request: offload one step — or one *batch* of fused steps.
///
/// The partitioner's offload batching fuses a run of consecutive
/// remotable steps into a single synthetic `Sequence`; that sequence
/// travels as ordinary task code (`step_xml`), and [`Self::batch`]
/// records how many developer-visible steps ride in the request, so
/// both sides can account multi-step round trips. Requests from older
/// peers without the `batch`/`node` fields decode as `batch = 1` with
/// no placement pin (the worker falls back to its local round-robin
/// pick).
#[derive(Debug, PartialEq)]
pub struct OffloadRequest {
    /// The step subtree as XAML text (the "task code").
    pub step_xml: String,
    /// Input variable values (reads of the step).
    pub inputs: BTreeMap<String, Value>,
    /// Variables the caller expects back (writes of the step).
    pub writes: Vec<String>,
    /// Number of fused steps carried by this request (>= 1).
    pub batch: u64,
    /// The leased cloud VM every activity in the request executes on
    /// (set by the migration manager after taking its scheduler lease).
    pub node: Option<PinnedNode>,
    /// Writes whose values may stay **cloud-resident**: instead of
    /// shipping these back by value, the worker publishes them into
    /// its node-local MDSS segment and returns an `mdss://resident/…`
    /// reference (the manager lists only writes that feed another
    /// remotable step — cloud-to-cloud hazard edges). Empty = ship
    /// everything by value (the A/B baseline and the legacy wire
    /// behaviour). Requests from older peers decode as empty.
    pub resident: Vec<String>,
    /// Namespace tag of the submitting run (`r<id>`, service mode):
    /// the worker publishes this request's residents under
    /// `mdss://resident/<run>-n<node>-<seq>/…`, so two concurrent runs
    /// sharing a cloud node's MDSS segment can never collide. Empty =
    /// the solo identity: the field stays off the wire entirely
    /// (encoding, signature and resident URIs are byte-identical to
    /// pre-service peers).
    pub run: String,
    /// Optional authentication tag over task code + inputs + writes
    /// (+ the placement pin, the resident list and the run tag when
    /// present; future-work §6, see [`super::security`]).
    pub sig: Option<String>,
}

/// Number of developer-visible steps a migration target carries: a
/// partitioner-fused batch is a `Sequence` whose children are all
/// remotable; anything else is a single step.
pub fn batch_len(step: &Step) -> u64 {
    match &step.kind {
        StepKind::Sequence(children)
            if children.len() >= 2 && children.iter().all(|c| c.remotable) =>
        {
            children.len() as u64
        }
        _ => 1,
    }
}

/// Response: the re-integration package.
#[derive(Debug, PartialEq)]
pub struct OffloadResponse {
    /// Written variable values (empty on error).
    pub outputs: BTreeMap<String, Value>,
    /// Simulated remote execution time in microseconds (cloud-node
    /// scaled compute + any cloud-side MDSS pulls).
    pub remote_sim_us: u64,
    /// Cloud-side WriteLine output.
    pub lines: Vec<String>,
    /// Name of the VM the request executed on (e.g. `cloud-3`), when
    /// the request carried a placement pin. Lets the local engine's
    /// trace record the node that actually ran the work.
    pub node: Option<String>,
    /// One note per output the worker kept cloud-resident instead of
    /// shipping by value (the matching entry in [`Self::outputs`] is a
    /// [`Value::Uri`] reference). The manager's residency registry is
    /// built from these. Empty for value-shipping peers.
    pub residents: Vec<ResidentNote>,
    /// Error message when remote execution failed.
    pub error: Option<String>,
}

/// Bookkeeping for one value published cloud-resident by the worker:
/// where it lives and how big it is — everything the manager's
/// registry needs for data-locality placement penalties, preemption
/// demotion, and leak-free teardown.
#[derive(Debug, Clone, PartialEq)]
pub struct ResidentNote {
    /// The `mdss://resident/…` reference the response's output carries.
    pub uri: String,
    /// Serialized payload size in bytes (feeds the scheduler's
    /// transfer-cost term and the demotion wire charge).
    pub bytes: u64,
    /// Global cloud-node index the value is homed on.
    pub node: usize,
}

/// Encode a workflow [`Value`] (tagged).
pub fn value_to_json(v: &Value) -> J {
    match v {
        Value::Num(n) => J::obj([("t", J::str("num")), ("v", J::num(*n))]),
        Value::Str(s) => J::obj([("t", J::str("str")), ("v", J::str(s.clone()))]),
        Value::Bool(b) => J::obj([("t", J::str("bool")), ("v", J::Bool(*b))]),
        Value::Uri(u) => J::obj([("t", J::str("uri")), ("v", J::str(u.clone()))]),
        Value::List(items) => J::obj([
            ("t", J::str("list")),
            ("v", J::Arr(items.iter().map(value_to_json).collect())),
        ]),
    }
}

/// Decode a workflow [`Value`].
pub fn value_from_json(j: &J) -> Result<Value> {
    let t = j.get("t")?.as_str()?;
    let v = j.get("v")?;
    Ok(match t {
        "num" => Value::Num(v.as_f64()?),
        "str" => Value::Str(v.as_str()?.to_string()),
        "bool" => Value::Bool(v.as_bool()?),
        "uri" => Value::Uri(v.as_str()?.to_string()),
        "list" => {
            let J::Arr(items) = v else { bail!("list value must be an array") };
            Value::List(items.iter().map(value_from_json).collect::<Result<_>>()?)
        }
        other => bail!("unknown value tag {other:?}"),
    })
}

fn map_to_json(m: &BTreeMap<String, Value>) -> J {
    J::Obj(m.iter().map(|(k, v)| (k.clone(), value_to_json(v))).collect())
}

fn map_from_json(j: &J) -> Result<BTreeMap<String, Value>> {
    let mut out = BTreeMap::new();
    for (k, v) in j.as_obj()? {
        out.insert(k.clone(), value_from_json(v)?);
    }
    Ok(out)
}

impl OffloadRequest {
    /// Package a step (or fused batch) for the wire. The placement pin
    /// ([`Self::node`]) is attached afterwards by the migration
    /// manager, once it holds a scheduler lease.
    pub fn package(step: &Step, inputs: BTreeMap<String, Value>, writes: &[String]) -> Self {
        Self {
            step_xml: xaml::step_to_xml(step),
            inputs,
            writes: writes.to_vec(),
            batch: batch_len(step),
            node: None,
            resident: Vec::new(),
            run: String::new(),
            sig: None,
        }
    }

    /// The canonical byte string authentication covers (everything the
    /// cloud will act on). The placement pin is folded in only when
    /// present, so signatures over pin-less requests stay
    /// byte-compatible with older peers.
    pub fn signable(&self) -> Vec<u8> {
        let mut msg = self.step_xml.clone().into_bytes();
        msg.extend_from_slice(jsonmini::to_string(&map_to_json(&self.inputs)).as_bytes());
        for w in &self.writes {
            msg.extend_from_slice(w.as_bytes());
            msg.push(0);
        }
        if let Some(n) = &self.node {
            msg.extend_from_slice(b"node");
            msg.extend_from_slice(&(n.index as u64).to_le_bytes());
            msg.extend_from_slice(&n.speed.to_bits().to_le_bytes());
        }
        // Folded only when present, like the pin: signatures over
        // resident-free requests stay byte-compatible with older peers.
        if !self.resident.is_empty() {
            msg.extend_from_slice(b"resident");
            for r in &self.resident {
                msg.extend_from_slice(r.as_bytes());
                msg.push(0);
            }
        }
        // The run tag namespaces the worker's resident URIs, so a
        // tampered tag must fail verification like a tampered pin.
        // Folded only when non-empty: solo signatures are unchanged.
        if !self.run.is_empty() {
            msg.extend_from_slice(b"run");
            msg.extend_from_slice(self.run.as_bytes());
            msg.push(0);
        }
        msg
    }

    /// Attach an authentication tag.
    pub fn sign(&mut self, key: &super::security::SigningKey) {
        self.sig = Some(key.sign(&self.signable()));
    }

    /// Verify the tag (false when absent or wrong).
    pub fn verify(&self, key: &super::security::SigningKey) -> bool {
        match &self.sig {
            Some(tag) => key.verify(&self.signable(), tag),
            None => false,
        }
    }

    /// Serialize.
    pub fn encode(&self) -> Vec<u8> {
        let mut fields = vec![
            ("kind", J::str("offload_request")),
            ("step_xml", J::str(self.step_xml.clone())),
            ("inputs", map_to_json(&self.inputs)),
            (
                "writes",
                J::Arr(self.writes.iter().map(|w| J::str(w.clone())).collect()),
            ),
            ("batch", J::num(self.batch as f64)),
            (
                "resident",
                J::Arr(self.resident.iter().map(|r| J::str(r.clone())).collect()),
            ),
            (
                "node",
                match &self.node {
                    Some(n) => J::obj([
                        ("index", J::num(n.index as f64)),
                        ("speed", J::num(n.speed)),
                    ]),
                    None => J::Null,
                },
            ),
            (
                "sig",
                match &self.sig {
                    Some(s) => J::str(s.clone()),
                    None => J::Null,
                },
            ),
        ];
        // Emitted only when non-empty so solo-mode requests stay
        // byte-identical to pre-service peers (request length feeds
        // the simulated uplink charge and the protocol-bytes stat).
        if !self.run.is_empty() {
            fields.push(("run", J::str(self.run.clone())));
        }
        jsonmini::to_string(&J::obj(fields)).into_bytes()
    }

    /// Deserialize.
    pub fn decode(bytes: &[u8]) -> Result<Self> {
        let text = std::str::from_utf8(bytes).context("request is not utf-8")?;
        let j = jsonmini::parse(text).context("parsing offload request")?;
        if j.get("kind")?.as_str()? != "offload_request" {
            bail!("not an offload_request");
        }
        Ok(Self {
            step_xml: j.get("step_xml")?.as_str()?.to_string(),
            inputs: map_from_json(j.get("inputs")?)?,
            writes: j
                .get("writes")?
                .as_arr()?
                .iter()
                .map(|w| Ok(w.as_str()?.to_string()))
                .collect::<Result<_>>()?,
            // Wire-compatible with pre-batching peers: absent -> 1.
            batch: match j.get_opt("batch") {
                None | Some(J::Null) => 1,
                Some(v) => (v.as_f64()? as u64).max(1),
            },
            // Wire-compatible with pre-tier peers: absent -> no pin.
            node: match j.get_opt("node") {
                None | Some(J::Null) => None,
                Some(v) => Some(PinnedNode {
                    index: v.get("index")?.as_usize()?,
                    speed: v.get("speed")?.as_f64()?,
                }),
            },
            // Wire-compatible with value-shipping peers: absent ->
            // nothing stays resident.
            resident: match j.get_opt("resident") {
                None | Some(J::Null) => Vec::new(),
                Some(v) => v
                    .as_arr()?
                    .iter()
                    .map(|r| Ok(r.as_str()?.to_string()))
                    .collect::<Result<_>>()?,
            },
            // Wire-compatible with pre-service peers: absent -> the
            // solo identity (legacy resident URIs).
            run: match j.get_opt("run") {
                None | Some(J::Null) => String::new(),
                Some(v) => v.as_str()?.to_string(),
            },
            sig: match j.get_opt("sig") {
                None | Some(J::Null) => None,
                Some(s) => Some(s.as_str()?.to_string()),
            },
        })
    }

    /// Parse the embedded task code back into a step tree.
    pub fn step(&self) -> Result<Step> {
        let el = crate::xmlmini::parse(&self.step_xml)
            .context("parsing packaged step XML")?;
        xaml::element_to_step(&el)
    }
}

impl OffloadResponse {
    /// Success response.
    pub fn ok(
        outputs: BTreeMap<String, Value>,
        remote_sim: std::time::Duration,
        lines: Vec<String>,
    ) -> Self {
        Self {
            outputs,
            remote_sim_us: remote_sim.as_micros() as u64,
            lines,
            node: None,
            residents: Vec::new(),
            error: None,
        }
    }

    /// Failure response.
    pub fn err(msg: String) -> Self {
        Self {
            outputs: BTreeMap::new(),
            remote_sim_us: 0,
            lines: Vec::new(),
            node: None,
            residents: Vec::new(),
            error: Some(msg),
        }
    }

    /// Serialize.
    pub fn encode(&self) -> Vec<u8> {
        jsonmini::to_string(&J::obj([
            ("kind", J::str("offload_response")),
            ("outputs", map_to_json(&self.outputs)),
            ("remote_sim_us", J::num(self.remote_sim_us as f64)),
            (
                "lines",
                J::Arr(self.lines.iter().map(|l| J::str(l.clone())).collect()),
            ),
            (
                "node",
                match &self.node {
                    Some(n) => J::str(n.clone()),
                    None => J::Null,
                },
            ),
            (
                "residents",
                J::Arr(
                    self.residents
                        .iter()
                        .map(|r| {
                            J::obj([
                                ("uri", J::str(r.uri.clone())),
                                ("bytes", J::num(r.bytes as f64)),
                                ("node", J::num(r.node as f64)),
                            ])
                        })
                        .collect(),
                ),
            ),
            (
                "error",
                match &self.error {
                    Some(e) => J::str(e.clone()),
                    None => J::Null,
                },
            ),
        ]))
        .into_bytes()
    }

    /// Deserialize.
    pub fn decode(bytes: &[u8]) -> Result<Self> {
        let text = std::str::from_utf8(bytes).context("response is not utf-8")?;
        let j = jsonmini::parse(text).context("parsing offload response")?;
        if j.get("kind")?.as_str()? != "offload_response" {
            bail!("not an offload_response");
        }
        Ok(Self {
            outputs: map_from_json(j.get("outputs")?)?,
            remote_sim_us: j.get("remote_sim_us")?.as_f64()? as u64,
            lines: j
                .get("lines")?
                .as_arr()?
                .iter()
                .map(|l| Ok(l.as_str()?.to_string()))
                .collect::<Result<_>>()?,
            node: match j.get_opt("node") {
                None | Some(J::Null) => None,
                Some(n) => Some(n.as_str()?.to_string()),
            },
            residents: match j.get_opt("residents") {
                None | Some(J::Null) => Vec::new(),
                Some(v) => v
                    .as_arr()?
                    .iter()
                    .map(|r| {
                        Ok(ResidentNote {
                            uri: r.get("uri")?.as_str()?.to_string(),
                            bytes: r.get("bytes")?.as_f64()? as u64,
                            node: r.get("node")?.as_usize()?,
                        })
                    })
                    .collect::<Result<_>>()?,
            },
            error: match j.get("error")? {
                J::Null => None,
                e => Some(e.as_str()?.to_string()),
            },
        })
    }
}

/// Operation carried by a [`RunRequest`].
#[derive(Debug, Clone, PartialEq)]
pub enum RunOp {
    /// Start a workflow; the service replies with the assigned run id.
    Submit {
        /// Billing identity the run's cloud spend is ledgered under
        /// (per-tenant budgets and fair-share weight, see
        /// [`crate::service`]).
        tenant: String,
        /// The workflow as XAML text — the same packaging as task
        /// code, just a whole document instead of a subtree.
        workflow_xml: String,
    },
    /// Query the lifecycle state of a run.
    Status {
        /// Run id from the submit reply.
        run: u64,
    },
    /// Request cooperative cancellation of a run. The service flips
    /// the run's [`crate::engine::RunContext`] flag; the run observes
    /// it at the next step boundary or offload checkpoint.
    Cancel {
        /// Run id from the submit reply.
        run: u64,
    },
}

/// Run-lifecycle request (submit / status / cancel), travelling over
/// the same signed wire as [`OffloadRequest`]. Authentication reuses
/// [`super::security`]: the tag covers the operation and every field
/// the service acts on, so a relayed submit cannot be retargeted to
/// another tenant and a status probe cannot be rewritten into a
/// cancellation.
#[derive(Debug, Clone, PartialEq)]
pub struct RunRequest {
    /// The requested operation.
    pub op: RunOp,
    /// Optional authentication tag over [`Self::signable`].
    pub sig: Option<String>,
}

impl RunRequest {
    /// Unsigned request around an operation.
    pub fn new(op: RunOp) -> Self {
        Self { op, sig: None }
    }

    /// The canonical byte string authentication covers: the operation
    /// name, then its fields (NUL-separated strings, little-endian run
    /// ids), mirroring [`OffloadRequest::signable`].
    pub fn signable(&self) -> Vec<u8> {
        let mut msg = Vec::new();
        match &self.op {
            RunOp::Submit { tenant, workflow_xml } => {
                msg.extend_from_slice(b"submit");
                msg.push(0);
                msg.extend_from_slice(tenant.as_bytes());
                msg.push(0);
                msg.extend_from_slice(workflow_xml.as_bytes());
            }
            RunOp::Status { run } => {
                msg.extend_from_slice(b"status");
                msg.push(0);
                msg.extend_from_slice(&run.to_le_bytes());
            }
            RunOp::Cancel { run } => {
                msg.extend_from_slice(b"cancel");
                msg.push(0);
                msg.extend_from_slice(&run.to_le_bytes());
            }
        }
        msg
    }

    /// Attach an authentication tag.
    pub fn sign(&mut self, key: &super::security::SigningKey) {
        self.sig = Some(key.sign(&self.signable()));
    }

    /// Verify the tag (false when absent or wrong).
    pub fn verify(&self, key: &super::security::SigningKey) -> bool {
        match &self.sig {
            Some(tag) => key.verify(&self.signable(), tag),
            None => false,
        }
    }

    /// Serialize.
    pub fn encode(&self) -> Vec<u8> {
        let mut fields = vec![("kind", J::str("run_request"))];
        match &self.op {
            RunOp::Submit { tenant, workflow_xml } => {
                fields.push(("op", J::str("submit")));
                fields.push(("tenant", J::str(tenant.clone())));
                fields.push(("workflow_xml", J::str(workflow_xml.clone())));
            }
            RunOp::Status { run } => {
                fields.push(("op", J::str("status")));
                fields.push(("run", J::num(*run as f64)));
            }
            RunOp::Cancel { run } => {
                fields.push(("op", J::str("cancel")));
                fields.push(("run", J::num(*run as f64)));
            }
        }
        fields.push((
            "sig",
            match &self.sig {
                Some(s) => J::str(s.clone()),
                None => J::Null,
            },
        ));
        jsonmini::to_string(&J::obj(fields)).into_bytes()
    }

    /// Deserialize.
    pub fn decode(bytes: &[u8]) -> Result<Self> {
        let text = std::str::from_utf8(bytes).context("run request is not utf-8")?;
        let j = jsonmini::parse(text).context("parsing run request")?;
        if j.get("kind")?.as_str()? != "run_request" {
            bail!("not a run_request");
        }
        let op = match j.get("op")?.as_str()? {
            "submit" => RunOp::Submit {
                tenant: j.get("tenant")?.as_str()?.to_string(),
                workflow_xml: j.get("workflow_xml")?.as_str()?.to_string(),
            },
            "status" => RunOp::Status { run: j.get("run")?.as_f64()? as u64 },
            "cancel" => RunOp::Cancel { run: j.get("run")?.as_f64()? as u64 },
            other => bail!("unknown run op {other:?}"),
        };
        Ok(Self {
            op,
            sig: match j.get_opt("sig") {
                None | Some(J::Null) => None,
                Some(s) => Some(s.as_str()?.to_string()),
            },
        })
    }
}

/// Reply to a [`RunRequest`]: a lifecycle snapshot of one run.
#[derive(Debug, Clone, PartialEq)]
pub struct RunReply {
    /// Run id the reply concerns (assigned by the service on submit).
    pub run: u64,
    /// Lifecycle state: `running`, `completed`, `failed` or
    /// `cancelled`.
    pub state: String,
    /// The run's WriteLine trace, present once it finished.
    pub lines: Vec<String>,
    /// Total cloud spend ledgered to the run so far ($).
    pub spend: f64,
    /// Error message for failed runs.
    pub error: Option<String>,
}

impl RunReply {
    /// Serialize.
    pub fn encode(&self) -> Vec<u8> {
        jsonmini::to_string(&J::obj([
            ("kind", J::str("run_reply")),
            ("run", J::num(self.run as f64)),
            ("state", J::str(self.state.clone())),
            (
                "lines",
                J::Arr(self.lines.iter().map(|l| J::str(l.clone())).collect()),
            ),
            ("spend", J::num(self.spend)),
            (
                "error",
                match &self.error {
                    Some(e) => J::str(e.clone()),
                    None => J::Null,
                },
            ),
        ]))
        .into_bytes()
    }

    /// Deserialize.
    pub fn decode(bytes: &[u8]) -> Result<Self> {
        let text = std::str::from_utf8(bytes).context("run reply is not utf-8")?;
        let j = jsonmini::parse(text).context("parsing run reply")?;
        if j.get("kind")?.as_str()? != "run_reply" {
            bail!("not a run_reply");
        }
        Ok(Self {
            run: j.get("run")?.as_f64()? as u64,
            state: j.get("state")?.as_str()?.to_string(),
            lines: j
                .get("lines")?
                .as_arr()?
                .iter()
                .map(|l| Ok(l.as_str()?.to_string()))
                .collect::<Result<_>>()?,
            spend: j.get("spend")?.as_f64()?,
            error: match j.get("error")? {
                J::Null => None,
                e => Some(e.as_str()?.to_string()),
            },
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workflow::StepKind;

    fn sample_step() -> Step {
        Step::new(
            "misfit",
            StepKind::InvokeActivity {
                activity: "at.misfit".into(),
                inputs: vec![("syn".into(), "syn".into()), ("obs".into(), "obs".into())],
                outputs: vec![("m".into(), "misfit".into())],
            },
        )
        .remotable()
    }

    #[test]
    fn request_roundtrip() {
        let mut inputs = BTreeMap::new();
        inputs.insert("syn".to_string(), Value::Uri("mdss://at/syn".into()));
        inputs.insert("k".to_string(), Value::Num(3.5));
        inputs.insert("quote".to_string(), Value::Str("a\"b\nc".into()));
        inputs.insert(
            "items".to_string(),
            Value::List(vec![Value::Num(1.0), Value::Str("x".into())]),
        );
        let mut req = OffloadRequest::package(&sample_step(), inputs, &["misfit".to_string()]);
        req.node = Some(PinnedNode { index: 7, speed: 8.0 });
        let back = OffloadRequest::decode(&req.encode()).unwrap();
        assert_eq!(back, req);
        assert_eq!(back.node, Some(PinnedNode { index: 7, speed: 8.0 }));
        // Task code round-trips to the same step tree.
        let step = back.step().unwrap();
        assert_eq!(step.display_name, "misfit");
        assert!(step.remotable);
    }

    #[test]
    fn legacy_request_without_node_field_decodes_unpinned() {
        let req = OffloadRequest::package(&sample_step(), BTreeMap::new(), &[]);
        assert_eq!(req.node, None);
        let legacy = String::from_utf8(req.encode())
            .unwrap()
            .replace("\"node\": null,", "")
            .replace("\"node\":null,", "");
        let back = OffloadRequest::decode(legacy.as_bytes()).unwrap();
        assert_eq!(back.node, None);
    }

    #[test]
    fn tampered_placement_pin_breaks_the_signature() {
        let key = crate::migration::security::SigningKey::new(b"k".to_vec());
        let mut req = OffloadRequest::package(&sample_step(), BTreeMap::new(), &[]);
        req.node = Some(PinnedNode { index: 1, speed: 4.0 });
        req.sign(&key);
        let mut back = OffloadRequest::decode(&req.encode()).unwrap();
        assert!(back.verify(&key));
        back.node = Some(PinnedNode { index: 0, speed: 0.5 });
        assert!(!back.verify(&key), "redirecting the pin must invalidate the tag");
    }

    #[test]
    fn resident_list_roundtrips_and_is_signed() {
        let key = crate::migration::security::SigningKey::new(b"k".to_vec());
        let mut req = OffloadRequest::package(&sample_step(), BTreeMap::new(), &["s1".into()]);
        req.resident = vec!["s1".to_string()];
        req.sign(&key);
        let back = OffloadRequest::decode(&req.encode()).unwrap();
        assert_eq!(back.resident, vec!["s1".to_string()]);
        assert!(back.verify(&key));
        // Dropping the resident list (forcing a value ship) must
        // invalidate the tag — the reference-passing decision is part
        // of what the cloud acts on.
        let mut tampered = OffloadRequest::decode(&req.encode()).unwrap();
        tampered.resident.clear();
        assert!(!tampered.verify(&key));
    }

    #[test]
    fn legacy_request_without_resident_field_decodes_empty() {
        let req = OffloadRequest::package(&sample_step(), BTreeMap::new(), &[]);
        let legacy = String::from_utf8(req.encode())
            .unwrap()
            .replace("\"resident\": [],", "")
            .replace("\"resident\":[],", "");
        assert!(!legacy.contains("resident"), "field must be gone from the legacy form");
        let back = OffloadRequest::decode(legacy.as_bytes()).unwrap();
        assert_eq!(back.resident, Vec::<String>::new());
        // A resident-free request signs identically with or without
        // the field, so older peers verify it unchanged.
        assert_eq!(req.signable(), back.signable());
    }

    #[test]
    fn resident_notes_roundtrip_and_legacy_decode() {
        let mut resp = OffloadResponse::ok(
            [("s1".to_string(), Value::Uri("mdss://resident/n2-1/s1".into()))].into(),
            std::time::Duration::from_micros(5),
            Vec::new(),
        );
        resp.residents =
            vec![ResidentNote { uri: "mdss://resident/n2-1/s1".into(), bytes: 64, node: 2 }];
        let back = OffloadResponse::decode(&resp.encode()).unwrap();
        assert_eq!(back, resp);
        // Responses from value-shipping peers (no residents field)
        // decode with an empty list.
        let plain = OffloadResponse::err("boom".into());
        let legacy = String::from_utf8(plain.encode())
            .unwrap()
            .replace("\"residents\": [],", "")
            .replace("\"residents\":[],", "");
        assert!(!legacy.contains("residents"));
        let back = OffloadResponse::decode(legacy.as_bytes()).unwrap();
        assert!(back.residents.is_empty());
    }

    #[test]
    fn response_roundtrip() {
        let mut outputs = BTreeMap::new();
        outputs.insert("misfit".to_string(), Value::Num(0.25));
        outputs.insert("done".to_string(), Value::Bool(true));
        let mut resp = OffloadResponse::ok(
            outputs,
            std::time::Duration::from_micros(12345),
            vec!["remote line".to_string()],
        );
        resp.node = Some("cloud-3".to_string());
        let back = OffloadResponse::decode(&resp.encode()).unwrap();
        assert_eq!(back, resp);
        assert_eq!(back.remote_sim_us, 12345);
        assert_eq!(back.node.as_deref(), Some("cloud-3"));
    }

    #[test]
    fn error_response() {
        let resp = OffloadResponse::err("boom".into());
        let back = OffloadResponse::decode(&resp.encode()).unwrap();
        assert_eq!(back.error.as_deref(), Some("boom"));
    }

    #[test]
    fn signing_roundtrip_and_tamper() {
        let key = crate::migration::security::SigningKey::new(b"k".to_vec());
        let mut req = OffloadRequest::package(
            &sample_step(),
            [("x".to_string(), Value::Num(1.0))].into(),
            &["y".to_string()],
        );
        assert!(!req.verify(&key), "unsigned must not verify");
        req.sign(&key);
        let back = OffloadRequest::decode(&req.encode()).unwrap();
        assert!(back.verify(&key));
        // Tamper with the task code after signing.
        let mut tampered = back;
        tampered.step_xml = tampered.step_xml.replace("at.misfit", "rm.rf");
        assert!(!tampered.verify(&key));
    }

    #[test]
    fn unsigned_decode_compatible() {
        // Requests without a sig field (older peers) still decode.
        let req = OffloadRequest::package(&sample_step(), BTreeMap::new(), &[]);
        let decoded = OffloadRequest::decode(&req.encode()).unwrap();
        assert_eq!(decoded.sig, None);
    }

    #[test]
    fn batch_length_detection_and_roundtrip() {
        let single = sample_step();
        assert_eq!(batch_len(&single), 1);
        let fused = Step::new(
            "batch(a+b)",
            StepKind::Sequence(vec![sample_step(), sample_step()]),
        );
        assert_eq!(batch_len(&fused), 2);
        // A sequence with a non-remotable member is not a batch.
        let mixed = Step::new(
            "seq",
            StepKind::Sequence(vec![sample_step(), Step::new("n", StepKind::Nop)]),
        );
        assert_eq!(batch_len(&mixed), 1);

        let req = OffloadRequest::package(&fused, BTreeMap::new(), &[]);
        assert_eq!(req.batch, 2);
        let back = OffloadRequest::decode(&req.encode()).unwrap();
        assert_eq!(back.batch, 2);
    }

    #[test]
    fn legacy_request_without_batch_field_decodes_as_single() {
        let req = OffloadRequest::package(&sample_step(), BTreeMap::new(), &[]);
        let legacy = String::from_utf8(req.encode())
            .unwrap()
            .replace("\"batch\": 1,", "")
            .replace("\"batch\":1,", "");
        let back = OffloadRequest::decode(legacy.as_bytes()).unwrap();
        assert_eq!(back.batch, 1);
    }

    #[test]
    fn wrong_kind_rejected() {
        let req = OffloadRequest::package(&sample_step(), BTreeMap::new(), &[]);
        assert!(OffloadResponse::decode(&req.encode()).is_err());
        assert!(OffloadRequest::decode(b"{}").is_err());
        assert!(OffloadRequest::decode(&[0xFF, 0xFE]).is_err());
        assert!(RunRequest::decode(&req.encode()).is_err());
        assert!(RunReply::decode(b"{}").is_err());
    }

    #[test]
    fn run_tag_roundtrips_and_is_signed() {
        let key = crate::migration::security::SigningKey::new(b"k".to_vec());
        let mut req = OffloadRequest::package(&sample_step(), BTreeMap::new(), &[]);
        req.run = "r7".to_string();
        req.sign(&key);
        let back = OffloadRequest::decode(&req.encode()).unwrap();
        assert_eq!(back.run, "r7");
        assert!(back.verify(&key));
        // Retargeting the namespace (redirecting where residents land)
        // must invalidate the tag, like redirecting the pin.
        let mut tampered = OffloadRequest::decode(&req.encode()).unwrap();
        tampered.run = "r8".to_string();
        assert!(!tampered.verify(&key));
    }

    #[test]
    fn solo_requests_keep_the_run_tag_off_the_wire() {
        // An empty run tag is not encoded at all and folds nothing
        // into the signature: solo-mode wire bytes and tags are
        // byte-identical to pre-service peers (request length feeds
        // the simulated uplink charge).
        let req = OffloadRequest::package(&sample_step(), BTreeMap::new(), &[]);
        assert_eq!(req.run, "");
        let encoded = String::from_utf8(req.encode()).unwrap();
        assert!(!encoded.contains("\"run\""));
        let back = OffloadRequest::decode(encoded.as_bytes()).unwrap();
        assert_eq!(back.run, "");
        assert_eq!(req.signable(), back.signable());
        let mut tagged = OffloadRequest::package(&sample_step(), BTreeMap::new(), &[]);
        tagged.run = "r1".to_string();
        assert_ne!(req.signable(), tagged.signable());
    }

    #[test]
    fn run_request_roundtrip_all_ops() {
        for op in [
            RunOp::Submit { tenant: "alice".into(), workflow_xml: "<Workflow/>".into() },
            RunOp::Status { run: 3 },
            RunOp::Cancel { run: 9 },
        ] {
            let req = RunRequest::new(op);
            let back = RunRequest::decode(&req.encode()).unwrap();
            assert_eq!(back, req);
        }
    }

    #[test]
    fn run_request_signature_covers_the_operation() {
        let key = crate::migration::security::SigningKey::new(b"k".to_vec());
        let mut req = RunRequest::new(RunOp::Submit {
            tenant: "alice".into(),
            workflow_xml: "<Workflow/>".into(),
        });
        assert!(!req.verify(&key), "unsigned must not verify");
        req.sign(&key);
        let back = RunRequest::decode(&req.encode()).unwrap();
        assert!(back.verify(&key));
        // Retargeting the tenant must invalidate the tag.
        let mut tampered = back.clone();
        tampered.op = RunOp::Submit {
            tenant: "mallory".into(),
            workflow_xml: "<Workflow/>".into(),
        };
        assert!(!tampered.verify(&key));
        // Rewriting a status probe into a cancellation must too.
        let mut probe = RunRequest::new(RunOp::Status { run: 3 });
        probe.sign(&key);
        let mut rewritten = probe.clone();
        rewritten.op = RunOp::Cancel { run: 3 };
        assert!(!rewritten.verify(&key));
    }

    #[test]
    fn run_reply_roundtrip() {
        let reply = RunReply {
            run: 4,
            state: "completed".into(),
            lines: vec!["hi".into()],
            spend: 0.25,
            error: None,
        };
        let back = RunReply::decode(&reply.encode()).unwrap();
        assert_eq!(back, reply);
        let failed = RunReply {
            run: 5,
            state: "failed".into(),
            lines: Vec::new(),
            spend: 0.0,
            error: Some("boom".into()),
        };
        assert_eq!(RunReply::decode(&failed.encode()).unwrap(), failed);
    }
}
