//! The migration manager (paper §3.3) — both sides.
//!
//! **Local side** ([`MigrationManager`], plugged into the engine as its
//! [`OffloadHandler`]): when the engine suspends at a migration point,
//! the manager
//!
//! 1. checks MDSS freshness for every data URI the step references —
//!    fresh cloud copies mean only task code crosses the wire, stale or
//!    missing ones are synchronized first (paper Fig 10);
//! 2. packages the step (task-code XML + input values) and sends it
//!    over the [`transport::Transport`], charging the uplink to the
//!    simulated WAN;
//! 3. receives the response, charges the downlink, and hands the
//!    outputs back to the engine for re-integration.
//!
//! **Cloud side** ([`CloudWorker`], a [`transport::RequestHandler`]):
//! deserializes the step, executes it on a cloud node with a remote
//! engine (offloading disabled — Property 3 guarantees no nesting),
//! and returns outputs + the remote simulated time.
//!
//! Placement goes through the [`crate::scheduler`]: each offload takes
//! a cloud-VM lease *before* packaging, pins the leased node into the
//! request ([`protocol::PinnedNode`]), and holds the lease for the
//! round trip — so concurrent offloads land by earliest estimated
//! finish time across heterogeneous tiers, queueing delay is charged
//! when they outnumber nodes, and the worker executes on exactly the
//! VM the scheduler chose. The [`Decision::CostBased`] gate keeps EWMA
//! cost averages per step name (adapting to drift instead of trusting
//! the first sample); its local estimate divides the observed
//! reference work by the configured `local_speed`, and its
//! reference-work average doubles as the scheduler's placement
//! weight. With [`ManagerConfig::admission`] the manager also applies
//! admission control: when the scheduler's queue-wait preview plus the
//! WAN-inclusive remote estimate pushes projected completion past the
//! local estimate, the step runs locally instead.
//! [`crate::scheduler::admission_cap`] is the offline planner variant
//! of the same principle (pure compute makespans over a known task
//! list, no WAN term). Partitioner-fused batches
//! arrive here as ordinary steps whose requests carry `batch > 1` —
//! one round trip for a whole run of remotable steps.
//!
//! **Money** (this PR's EC2-cost follow-up): cloud tiers may carry a
//! price per reference-second of work. The manager places leases under
//! a configurable time-vs-money [`Objective`]
//! ([`ManagerConfig::objective`]), keeps a cumulative spend ledger
//! ([`MigrationStats::spend`]), and — when [`ManagerConfig::budget`]
//! is set — declines any offload whose projected spend would push the
//! run past its budget (`budget = 0` disables offloading entirely; a
//! projected spend that lands exactly on the budget is still
//! admitted). Estimate-less first sightings project zero spend and are
//! serialized (one in flight at a time, see below), so a budgeted run
//! can overshoot by at most one unknown charge in total — the
//! irreducible cost of learning a price by observing it. A **steal
//! pass** ([`ManagerConfig::steal`], [`crate::scheduler::Lease::try_steal`])
//! runs between leasing and packaging: a lease queued behind in-flight
//! work re-pins to an idle VM that would finish strictly sooner,
//! bounded by the remaining budget — so a fast VM never idles while a
//! slow queue is deep unless money forbids the move. The re-pinned
//! node travels in the signed [`PinnedNode`] like any other placement,
//! and the trace records the VM the work actually executed on.
//!
//! **Concurrent offloads** (the engine's dataflow mode and `Parallel`
//! branches drive several offloads through one manager at once) are
//! first-class: when the budget or admission gate is on, the manager
//! previews *and takes* the cloud lease in one scheduler critical
//! section ([`crate::cloud::Platform::cloud_lease_preview_with`]), so
//! two concurrent placements can never both claim the same idle VM;
//! and the budget gate reserves each admitted offload's projected
//! spend in a shared ledger until the offload commits or fails, so
//! concurrent siblings with known estimates cannot collectively
//! overshoot the budget. Estimate-less first sightings project zero,
//! so a budgeted run **serializes** them ([`FirstSightGate`]): at most
//! one unknown-cost offload is in flight at a time, its real spend is
//! committed before the next is judged, and a burst of K
//! never-before-seen steps can therefore overshoot by at most one
//! offload in total (closing PR 4's once-per-step-name window; the
//! dependency-driven dispatcher makes such bursts the normal case,
//! not a corner). Known-cost offloads are never serialized. All
//! statistics continue to commit through the single
//! `MigrationStats::absorb` point.
//!
//! **Staleness re-probing** ([`ManagerConfig::decay_after`]): a losing
//! cost verdict that has gone `n` offload attempts without a fresh
//! observation — which is exactly what happens once the gate starts
//! declining a step — is no longer trusted blindly: the gate keeps
//! declining but admits one *probe* offload per window, whose round
//! trip blends into the EWMA (history is refreshed, never discarded),
//! so a stale estimate cannot gate a step forever and a single noisy
//! observation cannot erase a long history either. Estimates keep
//! serving the admission and budget gates while stale — in particular
//! a stale step still projects real spend, so decay does not re-open
//! the estimate-less budget window (an improvement over the PR-4
//! cliff, which forgot everything at once).

pub mod protocol;
pub mod security;
pub mod transport;

pub use protocol::{OffloadRequest, OffloadResponse, PinnedNode};
pub use security::SigningKey;
pub use transport::{serve_tcp, InProcTransport, TcpTransport, Transport};

use std::collections::BTreeMap;
use std::sync::{Arc, Condvar, Mutex};
use std::time::Duration;

use anyhow::{bail, Context, Result};

use crate::cloud::{Node, NodeKind};
use crate::engine::{
    ActivityRegistry, Engine, Event, OffloadHandler, OffloadOutcome, OffloadVerdict, RunContext,
    Services,
};
use crate::expr::Value;
use crate::mdss::{CloudState, Uri};
use crate::scheduler::{Objective, TenantArbiter};
use crate::workflow::Step;

/// Data-placement policy (E4 ablation).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DataPolicy {
    /// MDSS enabled (the paper's system): transfer application data
    /// only when the cloud copy is stale or missing.
    Mdss,
    /// MDSS disabled baseline: bundle all referenced application data
    /// with every offload and eagerly ship results back.
    BundleAlways,
}

/// Offload-decision policy (E8 ablation; the paper offloads every
/// remotable step unconditionally).
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Decision {
    /// Paper behaviour: always offload remotable steps.
    Always,
    /// Cost model: offload only when the estimated remote round trip
    /// beats the estimated local execution (per step name, from the
    /// history of observed costs; first sighting always offloads).
    CostBased,
}

/// Fault-handling and placement configuration for the offload path.
#[derive(Debug, Clone)]
pub struct ManagerConfig {
    /// Data-placement policy (MDSS freshness vs bundle-always).
    pub policy: DataPolicy,
    /// Offload-decision policy (always vs EWMA cost model).
    pub decision: Decision,
    /// Transport attempts per offload (>= 1).
    pub attempts: usize,
    /// After all attempts fail, decline so the engine runs the step
    /// locally instead of failing the workflow.
    pub local_fallback: bool,
    /// Sign requests with this key (worker must hold the same key).
    pub signing: Option<SigningKey>,
    /// Admission control (planner-driven): decline an offload when the
    /// scheduler's queue-wait preview plus the expected round trip
    /// would exceed the local estimate — queueing on a busy (slow)
    /// tier must not make offloading a loss. Needs cost history for
    /// the step; first sightings are always admitted.
    pub admission: bool,
    /// Time-vs-money objective for lease placement (`[migration]
    /// objective`). Only meaningful when tiers carry prices; on a free
    /// pool every objective behaves like [`Objective::Time`].
    pub objective: Objective,
    /// Spend budget (`[migration] budget`). `None` = unlimited (the
    /// paper's free cloud). With a budget, an offload is declined
    /// when the ledger has already reached the budget or when the
    /// projected spend (`previewed price × estimated reference work`)
    /// would push it past; a projection landing exactly on the budget
    /// is still admitted. `Some(0.0)` declines every offload.
    ///
    /// The ledger ([`MigrationStats::spend`]) is cumulative over the
    /// *manager's* lifetime. The CLI builds one manager per
    /// invocation, so there the budget is per-run; an embedded
    /// manager reused across several [`crate::engine::Engine::run`]
    /// calls enforces one budget across all of them — build a fresh
    /// manager per run for per-run budgets.
    pub budget: Option<f64>,
    /// Enable the work-stealing pass (`[migration] steal`): a lease
    /// queued behind in-flight work re-pins to an idle VM that would
    /// finish strictly sooner, within the remaining budget. Off by
    /// default (placement then exactly matches the lease the policy
    /// granted).
    pub steal: bool,
    /// Cost-model staleness re-probe rate (`[migration] decay_after`):
    /// once a losing `cost`-gate verdict has gone this many offload
    /// *attempts* (counting attempts for any step) without observing a
    /// round trip, the gate admits one **probe** offload instead of
    /// declining — the probe's observation blends into the EWMA
    /// (history is refreshed, not discarded), and if remote still
    /// loses the gate resumes declining until the next window opens
    /// another `decay_after` attempts later. Estimates keep serving
    /// the admission and budget gates while stale, so a stale step
    /// still projects real spend. `None` (the default) keeps verdicts
    /// live forever — a declined step is then never re-probed.
    pub decay_after: Option<u64>,
    /// Seeded preemption schedule (`[faults]` / `--fault-seed`): when
    /// set, the manager consults the plan once per placement attempt
    /// and a hit kills the leased VM mid-offload, triggering the
    /// retry-elsewhere recovery below. `None` (the default) is the
    /// paper's polite cloud — zero overhead on the offload path.
    pub faults: Option<Arc<crate::faults::FaultPlan>>,
    /// Bounded retry-elsewhere (`[faults] retries`): after a
    /// preemption, relocate the lease to a surviving VM and re-pin,
    /// re-sign and re-send — at most this many times per offload.
    /// Each relocation re-charges the uplink (the request ships
    /// again) and is budget-capped like the steal pass.
    pub preempt_retries: usize,
    /// When retries exhaust — or no affordable VM survives — recover
    /// by executing the step locally (`[faults] recover_local`, the
    /// default) instead of failing the workflow. `false` is the
    /// fail-the-run baseline the fig13j bench compares against.
    pub preempt_local: bool,
    /// Cloud-resident data plane (`[migration] resident`, default on):
    /// intermediates consumed only by later offloads stay published in
    /// the cloud worker's node-local MDSS segment and travel between
    /// chained offloads **by reference** — the response carries an
    /// `mdss://resident/…` URI instead of the value bytes, and
    /// placement gains a data-gravity term pulling the consumer onto
    /// the VM that already holds them. `false` is the ship-every-hop
    /// baseline (every intermediate crosses the WAN twice), the A/B
    /// arm the fig13k bench and the residency property tests compare
    /// against.
    pub resident: bool,
    /// Small-payload compression bypass (`[migration] compress_min`,
    /// bytes): MDSS payloads strictly smaller than this cross the wire
    /// uncompressed — below the cutoff the codec's framing overhead
    /// and CPU cost outweigh any byte savings. Zero disables the
    /// bypass (every payload goes through the codec, the historical
    /// behaviour). Applied to the shared MDSS at manager construction.
    pub compress_min: u64,
    /// Identity of the run this manager serves (service mode, see
    /// [`crate::service`]). The default — [`RunContext::solo`] — is
    /// the historical single-run-per-process identity: empty run tag
    /// (resident URIs and wire bytes unchanged), never cancelled. A
    /// service run's context namespaces the worker's resident URIs,
    /// scopes [`OffloadHandler::run_teardown`]'s sweep to this run,
    /// and adds two cooperative-cancellation checkpoints to the
    /// offload path (before leasing and after the response lands).
    pub run: RunContext,
    /// Per-tenant budget shared by every run the tenant has in flight
    /// (`[service] budget`, see [`crate::service`]). Enforced with the
    /// same committed+reserved reservation machinery as the per-run
    /// [`Self::budget`]: both gates must admit, each holds its own
    /// reservation for the round trip, and steals/evacuations are
    /// capped by the tighter of the two remaining budgets. `None` (the
    /// default) = no tenant cap.
    pub tenant_budget: Option<Arc<TenantBudget>>,
    /// Cross-tenant admission arbiter shared by every manager in the
    /// service process ([`crate::scheduler::TenantArbiter`]). When
    /// set, each offload checks in with its tenant's virtual-time
    /// account before taking a scheduler lease, so a heavy tenant
    /// cannot starve a light one of placement slots. `None` (the
    /// default) = uncontended FIFO, the solo behaviour.
    pub arbiter: Option<Arc<TenantArbiter>>,
}

impl ManagerConfig {
    /// Paper defaults: MDSS placement, always offload, one attempt,
    /// no fallback, no signing, no admission control, time objective,
    /// no budget, no stealing, no cost-record decay.
    pub fn new(policy: DataPolicy) -> Self {
        Self {
            policy,
            decision: Decision::Always,
            attempts: 1,
            local_fallback: false,
            signing: None,
            admission: false,
            objective: Objective::Time,
            budget: None,
            steal: false,
            decay_after: None,
            faults: None,
            preempt_retries: 2,
            preempt_local: true,
            resident: true,
            compress_min: 4096,
            run: RunContext::solo(),
            tenant_budget: None,
            arbiter: None,
        }
    }
}

/// Cumulative migration statistics.
#[derive(Debug, Clone, Copy, Default)]
pub struct MigrationStats {
    /// Completed offload round trips.
    pub offloads: u64,
    /// Protocol bytes (task code + values), excluding MDSS data.
    pub protocol_bytes: u64,
    /// Offloads where all data URIs were already fresh on the cloud.
    pub data_hits: u64,
    /// Offloads that required at least one data synchronization.
    pub data_syncs: u64,
    /// Simulated time spent in pre-offload data synchronization.
    pub sync_sim: Duration,
    /// Transport attempts that failed (retried or fallen back).
    pub failed_attempts: u64,
    /// Offloads declined by the cost model, by admission control, by
    /// fallback, or because no cloud nodes are configured.
    pub declined: u64,
    /// The subset of `declined` due to admission control (projected
    /// queueing past the local estimate).
    pub admission_declined: u64,
    /// Offloads whose cloud VM already had in-flight work (scheduler
    /// lease position > 0).
    pub queued: u64,
    /// Simulated time spent queueing behind in-flight offloads.
    pub queue_sim: Duration,
    /// Extra steps that rode in multi-step (batched) requests — each
    /// one is a WAN round trip the batching pass amortized away.
    pub batched_steps: u64,
    /// Cumulative money spent on completed offloads (`Σ leased price ×
    /// observed reference work`). The budget gate reads a shadow of
    /// this ledger that additionally reserves the projected spend of
    /// in-flight admitted offloads, so concurrent offloads with known
    /// estimates cannot collectively overshoot the budget.
    /// Estimate-less first sightings project zero but are serialized
    /// (one in flight at a time), so a budgeted run overshoots by at
    /// most one unknown charge in total; exact from then on.
    pub spend: f64,
    /// The subset of `declined` due to the budget gate (projected
    /// spend past [`ManagerConfig::budget`]).
    pub budget_declined: u64,
    /// Offloads whose lease was re-pinned by the work-stealing pass
    /// before packaging.
    pub stolen: u64,
    /// Injected VM preemptions survived by this manager's offloads
    /// (each one killed a leased VM mid-flight).
    pub preempted: u64,
    /// Successful retry-elsewhere relocations after a preemption (the
    /// offload re-pinned to a surviving VM and completed remotely).
    pub preempt_retried: u64,
    /// Preempted offloads that exhausted their retries (or found no
    /// affordable surviving VM) and recovered by local execution.
    /// Always a subset of `declined`.
    pub preempt_local: u64,
    /// Intermediates published into the cloud-resident data plane —
    /// each one is a result value that stayed cloud-side and travelled
    /// to its consumer by reference instead of crossing the WAN twice.
    pub residents_published: u64,
    /// Residents released by run teardown (every publish must be
    /// matched by a release or an invalidation — the leak invariant
    /// the failure-injection tests pin).
    pub residents_released: u64,
    /// Residents demoted to the local tier because their home VM was
    /// preempted — recovery re-materializes the value from the local
    /// copy instead of losing it with the node.
    pub residents_invalidated: u64,
}

impl MigrationStats {
    /// Fold a per-offload delta into the cumulative totals. Every
    /// offload commits exactly once through this single point — on
    /// success, decline *and* error paths — so a mid-offload failure
    /// can never leave half-applied statistics.
    fn absorb(&mut self, d: &MigrationStats) {
        self.offloads += d.offloads;
        self.protocol_bytes += d.protocol_bytes;
        self.data_hits += d.data_hits;
        self.data_syncs += d.data_syncs;
        self.sync_sim += d.sync_sim;
        self.failed_attempts += d.failed_attempts;
        self.declined += d.declined;
        self.admission_declined += d.admission_declined;
        self.queued += d.queued;
        self.queue_sim += d.queue_sim;
        self.batched_steps += d.batched_steps;
        self.spend += d.spend;
        self.budget_declined += d.budget_declined;
        self.stolen += d.stolen;
        self.preempted += d.preempted;
        self.preempt_retried += d.preempt_retried;
        self.preempt_local += d.preempt_local;
        self.residents_published += d.residents_published;
        self.residents_released += d.residents_released;
        self.residents_invalidated += d.residents_invalidated;
    }
}

/// Smoothing factor for the cost model's running averages.
const EWMA_ALPHA: f64 = 0.3;

/// Per-step-name cost history for [`Decision::CostBased`]:
/// exponentially-weighted moving averages over every observed round
/// trip, so the decision adapts to drifting costs instead of locking
/// in the first observation (the seed kept a single sample).
#[derive(Debug, Clone, Copy, Default)]
struct CostRecord {
    /// EWMA of the estimated local execution time (µs).
    local_est_us: f64,
    /// EWMA of the observed remote round-trip time (µs).
    remote_obs_us: f64,
    /// EWMA of the reference compute work (remote compute × node
    /// speed, µs on a speed-1.0 node) — the scheduler's placement
    /// weight, meaningful across tiers of different speeds.
    work_us: f64,
    /// Observations folded into the averages.
    samples: u64,
    /// Staleness-clock value at the last time the record was
    /// refreshed: an observation, or a probe the cost gate admitted
    /// after staleness (taking the probe consumes the window even if
    /// it never completes — see [`ManagerConfig::decay_after`]).
    last_tick: u64,
}

impl CostRecord {
    fn observe(&mut self, local_est: Duration, remote_obs: Duration, work: Duration) {
        let local_us = local_est.as_secs_f64() * 1e6;
        let remote_us = remote_obs.as_secs_f64() * 1e6;
        let work_us = work.as_secs_f64() * 1e6;
        if self.samples == 0 {
            self.local_est_us = local_us;
            self.remote_obs_us = remote_us;
            self.work_us = work_us;
        } else {
            self.local_est_us = EWMA_ALPHA * local_us + (1.0 - EWMA_ALPHA) * self.local_est_us;
            self.remote_obs_us =
                EWMA_ALPHA * remote_us + (1.0 - EWMA_ALPHA) * self.remote_obs_us;
            self.work_us = EWMA_ALPHA * work_us + (1.0 - EWMA_ALPHA) * self.work_us;
        }
        self.samples += 1;
    }

    /// Expected remote round trip, once observed.
    fn remote_estimate(&self) -> Option<Duration> {
        (self.samples > 0).then(|| Duration::from_secs_f64(self.remote_obs_us / 1e6))
    }

    /// Expected reference compute work, once observed (scheduler hint).
    fn work_estimate(&self) -> Option<Duration> {
        (self.samples > 0).then(|| Duration::from_secs_f64(self.work_us / 1e6))
    }
}

/// The cost model's shared state: per-step records plus the staleness
/// clock — `clock` advances once per offload attempt (any step), and
/// with [`ManagerConfig::decay_after`] = `n` a losing verdict that has
/// not been refreshed for more than `n` ticks admits one probe offload
/// instead of declining.
#[derive(Debug, Default)]
struct CostHistory {
    clock: u64,
    records: BTreeMap<String, CostRecord>,
}

/// The budget gate's ledger: money already charged plus the projected
/// spend of offloads currently in flight past the gate. Reservations
/// make the gate exact under concurrency — siblings admitted at the
/// same time each hold their projection until they commit, decline or
/// fail.
#[derive(Debug, Default)]
struct SpendLedger {
    /// Spend of completed offloads (mirrors [`MigrationStats::spend`]).
    committed: f64,
    /// Projected spend of in-flight admitted offloads.
    reserved: f64,
}

/// RAII hold on a [`SpendLedger`] reservation: released on drop, on
/// every path out of the offload — success (after the actual spend has
/// been committed), decline and error alike.
struct SpendReservation<'a> {
    ledger: Option<&'a Mutex<SpendLedger>>,
    amount: f64,
}

impl<'a> SpendReservation<'a> {
    fn none() -> Self {
        Self { ledger: None, amount: 0.0 }
    }

    fn held(ledger: &'a Mutex<SpendLedger>, amount: f64) -> Self {
        Self { ledger: Some(ledger), amount }
    }

    /// Re-project the reservation under an already-held ledger lock
    /// (the steal pass reads its budget cap, steals, and re-projects
    /// in one critical section so concurrent admissions cannot
    /// interleave).
    fn adjust_locked(&mut self, led: &mut SpendLedger, amount: f64) {
        if self.ledger.is_some() {
            led.reserved = (led.reserved - self.amount + amount).max(0.0);
        }
        self.amount = amount;
    }

    /// Commit the actual spend and release the projection in one
    /// ledger update (concurrent gates never see the charge and the
    /// reservation double-counted). Works for budget-less offloads
    /// too, whose reservation was never held.
    fn settle(&mut self, ledger: &Mutex<SpendLedger>, actual: f64) {
        let mut led = ledger.lock().unwrap();
        led.committed += actual;
        if self.ledger.is_some() {
            led.reserved = (led.reserved - self.amount).max(0.0);
        }
        self.ledger = None;
        self.amount = 0.0;
    }
}

impl Drop for SpendReservation<'_> {
    fn drop(&mut self) {
        if let Some(ledger) = self.ledger {
            let mut led = ledger.lock().unwrap();
            led.reserved = (led.reserved - self.amount).max(0.0);
        }
    }
}

/// Per-**tenant** spend account (service mode): one budget and one
/// committed+reserved ledger shared — via `Arc` in
/// [`ManagerConfig::tenant_budget`] — by every manager the tenant's
/// concurrent runs own. The offload path holds a [`SpendReservation`]
/// against this ledger alongside the per-run one, so concurrent runs
/// of one tenant cannot collectively overshoot the tenant's budget any
/// more than concurrent offloads of one run can overshoot the run's.
#[derive(Debug)]
pub struct TenantBudget {
    budget: f64,
    ledger: Mutex<SpendLedger>,
}

impl TenantBudget {
    /// New account with the given budget ($). Must be non-negative
    /// and finite.
    pub fn new(budget: f64) -> Arc<Self> {
        assert!(
            budget.is_finite() && budget >= 0.0,
            "tenant budget must be non-negative and finite"
        );
        Arc::new(Self { budget, ledger: Mutex::new(SpendLedger::default()) })
    }

    /// The configured budget ($).
    pub fn budget(&self) -> f64 {
        self.budget
    }

    /// Snapshot of the account as `(committed, reserved)` — same
    /// invariants as [`MigrationManager::ledger`], summed across every
    /// run charging this tenant.
    pub fn ledger(&self) -> (f64, f64) {
        let led = self.ledger.lock().unwrap();
        (led.committed, led.reserved)
    }
}

/// Serializes estimate-less **first sightings** while a budget is
/// configured. An offload with no cost history projects zero spend, so
/// K of them racing the budget gate used to each be admitted against
/// the same remaining budget — up to K unknown charges past the cap
/// (the PR-4 documented overshoot). With the gate, at most one
/// unknown-cost offload is in flight at a time: it commits its real
/// spend before the next one is judged, so same-name siblings inherit
/// its estimates and different-name siblings are declined the moment
/// the committed ledger reaches the budget. The overshoot window
/// shrinks from "once per step name" to "at most once per run" — the
/// irreducible minimum, since an unknown cost can only be learned by
/// observing it. Unused (and cost-free) when no budget is set.
struct FirstSightGate {
    busy: Mutex<bool>,
    cv: Condvar,
}

/// RAII hold on the [`FirstSightGate`]: released — with a wake-up for
/// waiting siblings — on every path out of the offload (commit,
/// decline and error alike), *after* the spend has been settled, so a
/// woken sibling always sees the updated ledger and estimates.
struct FirstSightPass<'a> {
    gate: Option<&'a FirstSightGate>,
}

impl FirstSightPass<'_> {
    fn none() -> Self {
        Self { gate: None }
    }
}

impl Drop for FirstSightPass<'_> {
    fn drop(&mut self) {
        if let Some(g) = self.gate {
            *g.busy.lock().unwrap() = false;
            g.cv.notify_all();
        }
    }
}

/// One entry in the manager's resident registry: where a published
/// intermediate lives ([`protocol::ResidentNote::node`] — the cloud VM
/// whose node-local MDSS segment holds it) and how big its serialized
/// payload is. Placement reads the registry to price pulling the value
/// onto each candidate VM; preemption recovery and run teardown drain
/// it.
#[derive(Debug, Clone, Copy)]
struct ResidentEntry {
    node: usize,
    bytes: u64,
}

/// Local-side migration manager.
pub struct MigrationManager {
    services: Arc<Services>,
    transport: Box<dyn Transport>,
    config: ManagerConfig,
    stats: Mutex<MigrationStats>,
    history: Mutex<CostHistory>,
    ledger: Mutex<SpendLedger>,
    first_sight: FirstSightGate,
    /// Live cloud-resident intermediates, keyed by their
    /// `mdss://resident/…` URI. Every publish lands here and every
    /// teardown sweep or preemption demotion removes it — an entry
    /// that survives [`OffloadHandler::run_teardown`] is a leak
    /// ([`Self::leaked_residents`]).
    residents: Mutex<BTreeMap<String, ResidentEntry>>,
}

impl MigrationManager {
    /// New manager over a transport with paper-default behaviour.
    pub fn new(
        services: Arc<Services>,
        transport: Box<dyn Transport>,
        policy: DataPolicy,
    ) -> Arc<Self> {
        Self::with_config(services, transport, ManagerConfig::new(policy))
    }

    /// New manager with explicit configuration.
    pub fn with_config(
        services: Arc<Services>,
        transport: Box<dyn Transport>,
        config: ManagerConfig,
    ) -> Arc<Self> {
        // The bypass threshold lives on the shared MDSS so both wire
        // directions (sync up, fetch-on-miss down) skip the codec for
        // sub-threshold payloads.
        services.mdss.set_compress_min(config.compress_min);
        Arc::new(Self {
            services,
            transport,
            config,
            stats: Mutex::new(Default::default()),
            history: Mutex::new(Default::default()),
            ledger: Mutex::new(Default::default()),
            first_sight: FirstSightGate { busy: Mutex::new(false), cv: Condvar::new() },
            residents: Mutex::new(BTreeMap::new()),
        })
    }

    /// Convenience: manager + in-process cloud worker pair sharing the
    /// same services and registry.
    pub fn in_proc(
        services: Arc<Services>,
        registry: Arc<ActivityRegistry>,
        policy: DataPolicy,
    ) -> Arc<Self> {
        let worker = CloudWorker::new(services.clone(), registry);
        Self::new(services, Box::new(InProcTransport::new(worker)), policy)
    }

    /// In-process pair with explicit configuration. The worker gets
    /// the same signing key when one is configured.
    pub fn in_proc_with_config(
        services: Arc<Services>,
        registry: Arc<ActivityRegistry>,
        config: ManagerConfig,
    ) -> Arc<Self> {
        let mut worker = CloudWorker::new_inner(services.clone(), registry);
        worker.require_key = config.signing.clone();
        Self::with_config(
            services,
            Box::new(InProcTransport::new(Arc::new(worker))),
            config,
        )
    }

    /// Cumulative statistics.
    pub fn stats(&self) -> MigrationStats {
        *self.stats.lock().unwrap()
    }

    /// Snapshot of the budget ledger as `(committed, reserved)`.
    ///
    /// Invariants the chaos tests pin: after every offload settles (or
    /// fails) `reserved` is `0.0` — reservations are released by RAII
    /// on every exit path — and `committed` tracks
    /// [`MigrationStats::spend`]: both totals accumulate exactly the
    /// same per-offload charges, each through a single commit point
    /// (`settle` / `absorb`), so a mid-offload failure can never leave
    /// them apart by a charge. Serialized runs agree bit-for-bit;
    /// concurrent runs may interleave the two accumulations in
    /// different orders, so agreement there is up to float
    /// re-association.
    pub fn ledger(&self) -> (f64, f64) {
        let led = self.ledger.lock().unwrap();
        (led.committed, led.reserved)
    }

    /// Number of cloud-resident intermediates still registered. After
    /// [`OffloadHandler::run_teardown`] this is zero on **every** path
    /// — success, decline, preemption recovery and transport failure
    /// alike (the failure-injection suite asserts it); a non-zero
    /// count after teardown is a leak.
    pub fn leaked_residents(&self) -> usize {
        self.residents.lock().unwrap().len()
    }

    /// Data-gravity term for the scheduler: per-cloud-node extra
    /// simulated µs placing this offload on that node would pay to
    /// pull its resident inputs there. A resident is free on its home
    /// VM and costs one estimated transfer of its payload anywhere
    /// else, so chained offloads gravitate to the VM that already
    /// holds their intermediates. Empty (locality-blind placement)
    /// when no input is resident.
    fn transfer_penalties(&self, inputs: &BTreeMap<String, Value>) -> Vec<f64> {
        let registry = self.residents.lock().unwrap();
        if registry.is_empty() {
            return Vec::new();
        }
        let nodes = self.services.platform.cloud_size();
        let net = &self.services.platform.network;
        let mut penalties = Vec::new();
        for value in inputs.values() {
            let Value::Uri(u) = value else { continue };
            let Some(entry) = registry.get(u) else { continue };
            if penalties.is_empty() {
                penalties = vec![0.0; nodes];
            }
            let pull_us = net.estimate(entry.bytes).as_secs_f64() * 1e6;
            for (i, p) in penalties.iter_mut().enumerate() {
                if i != entry.node {
                    *p += pull_us;
                }
            }
        }
        penalties
    }

    /// Preemption hit the VM at `node`: every resident homed there
    /// dies with its node-local segment. Recovery **demotes** each one
    /// to the local tier first — one metered downlink per resident
    /// (the bytes really cross the WAN to escape the dying node), then
    /// the cloud copy is dropped and the registry entry released — so
    /// re-materialization after recovery reads the local copy instead
    /// of failing on a missing URI.
    fn demote_residents(
        &self,
        node: usize,
        delta: &mut MigrationStats,
    ) -> Result<Duration> {
        let doomed: Vec<(String, ResidentEntry)> = {
            let registry = self.residents.lock().unwrap();
            registry
                .iter()
                .filter(|(_, e)| e.node == node)
                .map(|(u, e)| (u.clone(), *e))
                .collect()
        };
        let mdss = &self.services.mdss;
        let mut sim = Duration::ZERO;
        for (raw, _) in &doomed {
            let uri = Uri::parse(raw)?;
            // Fetch-on-miss into the local tier (metered), then drop
            // the doomed cloud copy.
            let (_, fetch) = mdss
                .get(NodeKind::Local, &uri)
                .with_context(|| format!("demoting resident {raw} off preempted VM"))?;
            sim += fetch;
            mdss.remove(NodeKind::Cloud, &uri);
            self.residents.lock().unwrap().remove(raw);
            delta.residents_invalidated += 1;
        }
        Ok(sim)
    }

    /// URIs referenced by the input values.
    fn data_uris(inputs: &BTreeMap<String, Value>) -> Result<Vec<Uri>> {
        inputs
            .values()
            .filter_map(|v| match v {
                Value::Uri(u) => Some(Uri::parse(u)),
                _ => None,
            })
            .collect()
    }

    /// Fig-10 data placement: returns the simulated time spent moving
    /// application data before the step itself is offloaded.
    fn place_data(&self, uris: &[Uri], stats: &mut MigrationStats) -> Result<Duration> {
        let mdss = &self.services.mdss;
        let mut sim = Duration::ZERO;
        let mut synced_any = false;
        for uri in uris {
            let must_sync = match self.config.policy {
                DataPolicy::Mdss => !matches!(
                    mdss.cloud_state(uri),
                    CloudState::Fresh | CloudState::Unknown
                ),
                DataPolicy::BundleAlways => true,
            };
            if must_sync {
                match self.config.policy {
                    DataPolicy::Mdss => {
                        let s = mdss.synchronize(uri)?;
                        sim += s.sim_time;
                        synced_any = true;
                    }
                    DataPolicy::BundleAlways => {
                        // Bundle the payload with the request even when
                        // the cloud already has it (version preserved,
                        // so results are not spuriously shipped back).
                        if let Some(item) = mdss.peek(NodeKind::Local, uri) {
                            sim += self
                                .services
                                .platform
                                .network
                                .transfer(item.payload.len() as u64);
                            mdss.replicate(NodeKind::Local, NodeKind::Cloud, uri)?;
                            synced_any = true;
                        }
                    }
                }
            }
        }
        if synced_any {
            stats.data_syncs += 1;
        } else if !uris.is_empty() {
            stats.data_hits += 1;
        }
        Ok(sim)
    }
}

impl MigrationManager {
    /// Cost-model gate: should this step be offloaded at all? Compares
    /// the EWMA of observed round trips against the EWMA local
    /// estimate.
    ///
    /// **Staleness re-probing** ([`ManagerConfig::decay_after`] = `n`):
    /// a losing verdict that has gone more than `n` offload attempts
    /// without a fresh observation keeps gating, but admits one
    /// *probe* offload — the probe's round trip refreshes the EWMA
    /// (blended into the history, never discarding it), and if remote
    /// still loses the gate resumes declining until the next window
    /// `n` attempts later. Taking the probe touches the record, so
    /// concurrent stale attempts cannot all probe at once, and a probe
    /// that never observes a round trip (declined downstream, or
    /// failed) still closes the window it consumed.
    fn should_offload(&self, step: &Step) -> Option<String> {
        if self.config.decision == Decision::Always {
            return None;
        }
        let mut history = self.history.lock().unwrap();
        let clock = history.clock;
        let Some(rec) = history.records.get_mut(&step.display_name) else {
            return None;
        };
        if rec.samples > 0 && rec.remote_obs_us >= rec.local_est_us {
            if let Some(n) = self.config.decay_after {
                // The clock already counts the *current* attempt, so
                // the number of intervening attempts without an
                // observation is staleness - 1: probe strictly past
                // `n`, or `decay_after = 1` would re-probe on the very
                // next attempt and effectively disable the gate.
                if clock.saturating_sub(rec.last_tick) > n {
                    rec.last_tick = clock;
                    return None;
                }
            }
            return Some(format!(
                "cost model: remote {:.0}ms >= local {:.0}ms for '{}' (ewma over {} run(s))",
                rec.remote_obs_us / 1e3,
                rec.local_est_us / 1e3,
                step.display_name,
                rec.samples
            ));
        }
        None
    }

    /// One locked history lookup serving the whole offload path:
    /// the reference-work estimate (the scheduler's
    /// earliest-finish-time placement weight) and the
    /// `(local estimate, expected remote round trip)` pair the
    /// admission gate compares. `(None, None)` before any observation.
    /// A stale record (see [`ManagerConfig::decay_after`]) still
    /// serves its estimates: an aged EWMA is a weaker signal, not a
    /// missing one — in particular a stale step's projected spend
    /// stays real money, so decay no longer re-opens the estimate-less
    /// budget window.
    fn estimates(&self, step: &Step) -> (Option<Duration>, Option<(Duration, Duration)>) {
        let history = self.history.lock().unwrap();
        match history.records.get(&step.display_name) {
            Some(rec) => (
                rec.work_estimate(),
                rec.remote_estimate().map(|remote| {
                    (Duration::from_secs_f64(rec.local_est_us / 1e6), remote)
                }),
            ),
            None => (None, None),
        }
    }

    /// The estimates plus, when needed, a hold on the first-sighting
    /// gate: with a budget configured, an offload with no cost history
    /// waits here until no other estimate-less offload is in flight,
    /// then re-reads the estimates under the gate — a sibling that
    /// just settled may have seeded the record, in which case this is
    /// no longer a first sighting and the gate is released
    /// immediately. The returned pass is held for the whole round trip
    /// and released on every exit path. Budget-less runs (and steps
    /// with history) skip the gate entirely.
    fn first_sighting_pass(
        &self,
        step: &Step,
    ) -> (Option<Duration>, Option<(Duration, Duration)>, FirstSightPass<'_>) {
        let (work, cost) = self.estimates(step);
        let budgeted =
            self.config.budget.is_some() || self.config.tenant_budget.is_some();
        if !budgeted || work.is_some() {
            return (work, cost, FirstSightPass::none());
        }
        {
            let mut busy = self.first_sight.busy.lock().unwrap();
            while *busy {
                busy = self.first_sight.cv.wait(busy).unwrap();
            }
            *busy = true;
        }
        let pass = FirstSightPass { gate: Some(&self.first_sight) };
        let (work, cost) = self.estimates(step);
        if work.is_some() {
            drop(pass); // no longer a first sighting: release + wake
            return (work, cost, FirstSightPass::none());
        }
        (work, cost, pass)
    }

    /// Fold an observed round trip into the cost model.
    /// `remote_compute` is simulated time on the leased node (speed
    /// `node_speed`), so the reference work is `remote_compute ×
    /// node_speed` and the local estimate divides that by the local
    /// tier's speed — the `CostBased` gate stays unbiased when
    /// `local_speed != 1.0` (the old formula silently assumed a
    /// speed-1.0 local cluster). Observations always blend into the
    /// existing EWMA — a probe after staleness refreshes the history
    /// instead of discarding it.
    fn record_costs(
        &self,
        step: &Step,
        remote_total: Duration,
        remote_compute: Duration,
        node_speed: f64,
    ) {
        let work = Duration::from_secs_f64(remote_compute.as_secs_f64() * node_speed);
        let local_est = Duration::from_secs_f64(
            work.as_secs_f64() / self.services.platform.config.local_speed,
        );
        let mut history = self.history.lock().unwrap();
        let clock = history.clock;
        let rec = history.records.entry(step.display_name.clone()).or_default();
        rec.observe(local_est, remote_total, work);
        rec.last_tick = clock;
    }
}

impl OffloadHandler for MigrationManager {
    fn offload(
        &self,
        step: &Step,
        inputs: BTreeMap<String, Value>,
        writes: &[String],
    ) -> Result<OffloadVerdict> {
        self.offload_with(step, inputs, writes, &[])
    }

    fn offload_with(
        &self,
        step: &Step,
        inputs: BTreeMap<String, Value>,
        writes: &[String],
        resident: &[String],
    ) -> Result<OffloadVerdict> {
        // Every counter for this offload accumulates in a local delta
        // and commits exactly once — success, decline or error — so a
        // mid-offload failure can't leave half-applied stats.
        let mut delta = MigrationStats::default();
        let result = self.offload_inner(step, inputs, writes, resident, &mut delta);
        self.stats.lock().unwrap().absorb(&delta);
        result
    }

    /// End-of-run residency sweep: drop every resident item this run
    /// published from both MDSS tiers (including stray local copies
    /// cached by fetch-on-miss) and drain the registry. Runs on
    /// success *and* failure paths — cancellation included — so no
    /// published intermediate outlives its run:
    /// [`Self::leaked_residents`] is zero afterwards, always. The solo
    /// identity's empty tag sweeps the whole `resident` namespace (the
    /// historical behaviour); a service run sweeps only its own
    /// `resident/r<id>-…` names, leaving concurrent runs' residents
    /// untouched.
    fn run_teardown(&self) -> Result<()> {
        self.services.mdss.sweep_resident_run(&self.config.run.tag());
        let drained = {
            let mut registry = self.residents.lock().unwrap();
            let n = registry.len() as u64;
            registry.clear();
            n
        };
        if drained > 0 {
            self.stats.lock().unwrap().residents_released += drained;
        }
        Ok(())
    }
}

impl MigrationManager {
    fn offload_inner(
        &self,
        step: &Step,
        inputs: BTreeMap<String, Value>,
        writes: &[String],
        resident: &[String],
        delta: &mut MigrationStats,
    ) -> Result<OffloadVerdict> {
        // Cancellation checkpoint (service mode): a cancelled run
        // takes no new leases and reserves no new spend. Nothing is
        // held yet, so there is nothing to release.
        if self.config.run.cancelled() {
            bail!(
                "run {} cancelled before offloading '{}'",
                self.config.run.id(),
                step.display_name
            );
        }

        // Staleness clock: one tick per offload attempt, so cost
        // records that stop being refreshed age out under
        // `decay_after` even when every attempt is declined.
        {
            let mut history = self.history.lock().unwrap();
            history.clock = history.clock.saturating_add(1);
        }

        // 0a. A zero-cloud platform declines instead of panicking
        //     (regression: `PlatformConfig { tiers: vec![], .. }`).
        if self.services.platform.cloud_size() == 0 {
            delta.declined += 1;
            return Ok(OffloadVerdict::Declined {
                reason: "no cloud nodes configured; executing locally".into(),
            });
        }

        // 0b. Cost-model gate (E8; the paper always offloads).
        if let Some(reason) = self.should_offload(step) {
            delta.declined += 1;
            return Ok(OffloadVerdict::Declined { reason });
        }

        // 0c-pre. Estimate-less first sightings project zero spend, so
        //     with a budget on, K of them racing the gate could each
        //     be admitted against the same remaining budget. The
        //     first-sighting gate serializes them: at most one
        //     unknown-cost offload is in flight at a time, it settles
        //     its real spend before the next is judged, and the pass
        //     (held through the whole round trip, released on every
        //     exit) wakes the waiters into an informed world — either
        //     fresh estimates for their step name, or a committed
        //     ledger at/past the budget. Skipped without a budget.
        let (work_est, cost_est, _first_sight) = self.first_sighting_pass(step);

        // 0c-arb. Cross-tenant arbitration (service mode): check in
        //     with the shared arbiter before taking any lease. Under
        //     fair share, an offload from the tenant with the lowest
        //     weighted virtual time proceeds immediately; others block
        //     until their account is cheapest — so a heavy tenant
        //     drains the pool no faster than its weight allows. The
        //     charge is the reference-work estimate (zero for first
        //     sightings: unknown work rides free once, then its
        //     observed cost is charged from the next offload on).
        if let Some(arb) = &self.config.arbiter {
            arb.admit(
                self.config.run.tenant(),
                work_est.unwrap_or(Duration::ZERO),
            );
        }

        // 0c/0d. Budget and admission gates share ONE scheduler
        //     critical section: when either gate is on, the manager
        //     previews *and takes* the lease atomically
        //     (`cloud_lease_preview_with`), so concurrent offloads
        //     from sibling steps can never both reason about — and
        //     then both claim — the same idle VM. A gate that declines
        //     simply drops the lease, releasing the slot. Skipped
        //     entirely when neither gate is on: the probe costs a
        //     slots lock plus an O(pool) policy scan per offload.
        // Data gravity: when any input is a cloud-resident reference,
        // every candidate VM is scored with the estimated time to pull
        // the resident payloads there (zero on their home VM), so the
        // consumer lands where its data already lives. Computed once
        // and shared by both lease paths below.
        let penalties = if self.config.resident {
            self.transfer_penalties(&inputs)
        } else {
            Vec::new()
        };
        let data_gravity = penalties.iter().any(|p| *p > 0.0);

        let mut reservation = SpendReservation::none();
        let mut tenant_res = SpendReservation::none();
        let gated = self.config.budget.is_some()
            || self.config.admission
            || self.config.tenant_budget.is_some();
        let early_lease = if gated {
            let (preview, lease) = self
                .services
                .platform
                .cloud_lease_preview_transfer(work_est, self.config.objective, &penalties)
                .with_context(|| format!("leasing a cloud VM for '{}'", step.display_name))?;

            // 0c. Budget gate: a run that has already spent its budget
            //     offloads nothing more, and a projected spend
            //     (previewed node's price × estimated reference work)
            //     that would push the ledger past the budget sends the
            //     step home. The ledger counts committed spend plus
            //     the reservations of in-flight admitted offloads, so
            //     concurrent siblings cannot collectively overshoot;
            //     this offload's own reservation is released when it
            //     commits, declines or fails. Exactly reaching the
            //     budget is allowed; estimate-less first sightings
            //     project zero but arrive serialized through the
            //     first-sighting gate above, so at most one unknown
            //     charge can cross the boundary per run (the module
            //     doc spells this out).
            if let Some(budget) = self.config.budget {
                let projected = work_est.map_or(0.0, |w| preview.price * w.as_secs_f64());
                let mut ledger = self.ledger.lock().unwrap();
                let (committed, reserved) = (ledger.committed, ledger.reserved);
                if committed >= budget || committed + reserved + projected > budget {
                    drop(ledger);
                    // Release the probe lease as a dry run: the
                    // round-robin cursor (when that policy is active)
                    // must not record a placement that never happened.
                    lease.cancel();
                    delta.declined += 1;
                    delta.budget_declined += 1;
                    // Separate actual spend from in-flight projections
                    // in the notice; without concurrency the in-flight
                    // part is absent and the line matches the PR-3
                    // format byte for byte.
                    let inflight = if reserved > 0.0 {
                        format!(" (+{reserved:.3} in flight)")
                    } else {
                        String::new()
                    };
                    return Ok(OffloadVerdict::Declined {
                        reason: format!(
                            "budget: spent {committed:.3}{inflight} of {budget:.3}, \
                             projected +{projected:.3} for '{}' — executing locally",
                            step.display_name
                        ),
                    });
                }
                ledger.reserved += projected;
                drop(ledger);
                reservation = SpendReservation::held(&self.ledger, projected);
            }

            // 0c-ten. Tenant budget gate (service mode): the same
            //     committed+reserved discipline as the run gate above,
            //     against the account every run of this tenant shares.
            //     Both gates must admit; a tenant decline releases the
            //     probe lease and lets the run reservation (if held)
            //     unwind by RAII.
            if let Some(tb) = &self.config.tenant_budget {
                let projected = work_est.map_or(0.0, |w| preview.price * w.as_secs_f64());
                let mut tled = tb.ledger.lock().unwrap();
                let (committed, reserved) = (tled.committed, tled.reserved);
                if committed >= tb.budget
                    || committed + reserved + projected > tb.budget
                {
                    drop(tled);
                    lease.cancel();
                    delta.declined += 1;
                    delta.budget_declined += 1;
                    let inflight = if reserved > 0.0 {
                        format!(" (+{reserved:.3} in flight)")
                    } else {
                        String::new()
                    };
                    return Ok(OffloadVerdict::Declined {
                        reason: format!(
                            "tenant budget: '{}' spent {committed:.3}{inflight} of \
                             {:.3}, projected +{projected:.3} for '{}' — executing \
                             locally",
                            self.config.run.tenant(),
                            tb.budget,
                            step.display_name
                        ),
                    });
                }
                tled.reserved += projected;
                drop(tled);
                tenant_res = SpendReservation::held(&tb.ledger, projected);
            }

            // 0d. Admission control: if the projected queueing behind
            //     in-flight work plus the expected round trip exceeds
            //     the local estimate, running locally is faster right
            //     now. Deliberately only triggers under contention
            //     (active leases or pending work on the previewed
            //     node) — the intrinsic remote-vs-local tradeoff is
            //     the CostBased gate's job.
            if self.config.admission {
                if let Some((local_est, remote_est)) = cost_est {
                    // Projected queueing on the previewed node: the
                    // larger of its pending-work drain time and the
                    // position-based projection the engine actually
                    // charges (position × node-scaled compute, no WAN
                    // term) — so in-flight leases without a work
                    // estimate still count, without over-declining
                    // WAN-dominated steps.
                    let p = preview;
                    let scaled_work = work_est.map_or(Duration::ZERO, |w| {
                        Duration::from_secs_f64(w.as_secs_f64() / p.speed)
                    });
                    let queue = p.wait.max(scaled_work.saturating_mul(p.active as u32));
                    let contended = p.active > 0 || p.wait > Duration::ZERO;
                    if contended && queue + remote_est >= local_est {
                        lease.cancel();
                        delta.declined += 1;
                        delta.admission_declined += 1;
                        return Ok(OffloadVerdict::Declined {
                            reason: format!(
                                "admission control: ~{}ms queued on cloud-{} pushes \
                                 completion past the ~{}ms local estimate for '{}'",
                                queue.as_millis(),
                                p.node,
                                local_est.as_millis(),
                                step.display_name
                            ),
                        });
                    }
                }
            }
            Some(lease)
        } else {
            None
        };

        let net = &self.services.platform.network;
        let mut sim = Duration::ZERO;

        // 1. Data placement (MDSS freshness / bundling).
        let uris = Self::data_uris(&inputs)?;
        let sync_sim = self.place_data(&uris, delta)?;
        delta.sync_sim += sync_sim;
        sim += sync_sim;

        // 2. Lease a cloud VM (objective-weighted placement across
        //    tiers, weighted by the cost model's reference-work
        //    estimate) *before* packaging, so the leased node rides in
        //    the signed request and pins remote execution. The lease
        //    is held across the round trip so concurrent offloads
        //    observe each other's occupancy. When a gate already took
        //    the lease in its critical section above, that lease is
        //    simply reused.
        let mut lease = match early_lease {
            Some(lease) => lease,
            None => self
                .services
                .platform
                .cloud_lease_preview_transfer(work_est, self.config.objective, &penalties)
                .map(|(_, lease)| lease)
                .with_context(|| format!("leasing a cloud VM for '{}'", step.display_name))?,
        };

        // 2b. Steal pass: if this lease queued behind in-flight work
        //     while another VM idles and would finish strictly sooner,
        //     re-pin it there — bounded by the remaining budget, so a
        //     cost-placed lease only upgrades to an expensive fast VM
        //     when the run can afford it. The re-pinned node is what
        //     gets packaged, signed and executed below. Skipped under
        //     data gravity: the steal scores pure queue depth, and
        //     yanking a consumer off the VM that holds its resident
        //     inputs would silently re-add the transfer the placement
        //     just avoided.
        if self.config.steal && !data_gravity {
            // ONE critical section per ledger covers the cap read, the
            // steal and the re-projection — a concurrent sibling's
            // admission or steal cannot interleave between them, so
            // the collective reservation can never exceed either
            // budget. (Lock order is always run ledger → tenant ledger
            // → slots, never the reverse; `try_steal` touches only the
            // scheduler's slots lock. Budget-less runs lock nothing.)
            let mut run_led =
                self.config.budget.is_some().then(|| self.ledger.lock().unwrap());
            let mut ten_led = self
                .config
                .tenant_budget
                .as_ref()
                .map(|tb| (tb, tb.ledger.lock().unwrap()));
            // Remaining budget net of committed spend and the *other*
            // in-flight reservations (the steal replaces this
            // offload's own projection, so it doesn't count against
            // itself) — the tighter of the run and tenant caps.
            let mut cap: Option<f64> = None;
            if let (Some(b), Some(led)) = (self.config.budget, &run_led) {
                cap = Some((b - led.committed - (led.reserved - reservation.amount)).max(0.0));
            }
            if let Some((tb, led)) = &ten_led {
                let t = (tb.budget - led.committed - (led.reserved - tenant_res.amount))
                    .max(0.0);
                cap = Some(cap.map_or(t, |c| c.min(t)));
            }
            if lease.try_steal(cap).is_some() {
                delta.stolen += 1;
                // The re-pin changed the projected spend: keep the
                // reservations in step so concurrent admissions see
                // the dearer placement.
                let projected = work_est.map_or(0.0, |w| lease.price * w.as_secs_f64());
                if let Some(led) = &mut run_led {
                    reservation.adjust_locked(led, projected);
                }
                if let Some((_, led)) = &mut ten_led {
                    tenant_res.adjust_locked(led, projected);
                }
            }
        }
        // 3. Package once; pin + sign + uplink *per placement attempt*.
        //    Under the hostile-cloud model ([`ManagerConfig::faults`])
        //    the leased VM can be preempted after the request shipped:
        //    the manager then relocates the lease to a surviving VM
        //    ([`Lease::evacuate`]), re-pins, re-signs (`sign`
        //    overwrites the tag) and re-sends — re-charging the uplink,
        //    because the bytes really cross the WAN again. Relocations
        //    are bounded by [`ManagerConfig::preempt_retries`] and
        //    budget-capped exactly like the steal pass; when they
        //    exhaust, the step recovers locally
        //    ([`OffloadVerdict::RecoveredLocal`]) or — with
        //    `preempt_local` off — fails the run (the fig13j
        //    baseline).
        let mut req = OffloadRequest::package(step, inputs, writes);
        // Residency plan: writes the IR classified as cloud-to-cloud
        // travel in the request so the worker publishes them node-side
        // and answers with references instead of value bytes. The list
        // rides inside the signature (`signable` folds it), so a
        // tampered plan fails verification like tampered task code.
        if self.config.resident {
            req.resident =
                resident.iter().filter(|r| writes.contains(*r)).cloned().collect();
        }
        // Run namespace tag: the worker publishes this request's
        // residents under `mdss://resident/<tag>-n<node>-<seq>/…`, so
        // concurrent runs sharing the cloud MDSS cannot collide. The
        // solo identity's empty tag stays off the wire entirely.
        req.run = self.config.run.tag();
        let mut recovery: Vec<Event> = Vec::new();
        let mut relocations = 0usize;
        let mut uplink_bytes = 0u64;
        let (req_bytes, node) = loop {
            let node = self
                .services
                .platform
                .cloud_node_at(lease.node)
                .with_context(|| format!("resolving the leased VM for '{}'", step.display_name))?;
            req.node = Some(PinnedNode { index: node.index, speed: node.speed });
            if let Some(key) = &self.config.signing {
                req.sign(key);
            }
            let bytes = req.encode();
            uplink_bytes += bytes.len() as u64;
            sim += net.transfer(bytes.len() as u64);

            // 3b. Does this placement survive the hostile cloud?
            let preempted = self
                .config
                .faults
                .as_ref()
                .is_some_and(|fp| fp.preempts(&step.display_name));
            if !preempted {
                break (bytes, node);
            }
            delta.preempted += 1;
            recovery.push(Event::OffloadPreempted {
                step: step.display_name.clone(),
                node: node.name(),
            });
            // The killed VM must provision again before serving anyone
            // — occupancy is untouched (this lease still owns its slot
            // until it evacuates or drops, exactly once either way).
            self.services.platform.cloud_scheduler().invalidate(lease.node);
            // The node-local MDSS segment dies with the VM: demote its
            // residents to the local tier (metered — the bytes really
            // cross the WAN to survive) so recovery re-materializes
            // them instead of failing on missing URIs.
            sim += self.demote_residents(lease.node, delta)?;

            let relocated = if relocations < self.config.preempt_retries {
                // Same single-critical-section discipline as the steal
                // pass above: cap reads, evacuation and re-projection
                // are atomic against concurrent admissions and steals,
                // under the same run ledger → tenant ledger → slots
                // lock order.
                let mut run_led =
                    self.config.budget.is_some().then(|| self.ledger.lock().unwrap());
                let mut ten_led = self
                    .config
                    .tenant_budget
                    .as_ref()
                    .map(|tb| (tb, tb.ledger.lock().unwrap()));
                let mut cap: Option<f64> = None;
                if let (Some(b), Some(led)) = (self.config.budget, &run_led) {
                    cap = Some(
                        (b - led.committed - (led.reserved - reservation.amount)).max(0.0),
                    );
                }
                if let Some((tb, led)) = &ten_led {
                    let t = (tb.budget - led.committed
                        - (led.reserved - tenant_res.amount))
                        .max(0.0);
                    cap = Some(cap.map_or(t, |c| c.min(t)));
                }
                match lease.evacuate(cap) {
                    Some(_) => {
                        let projected =
                            work_est.map_or(0.0, |w| lease.price * w.as_secs_f64());
                        if let Some(led) = &mut run_led {
                            reservation.adjust_locked(led, projected);
                        }
                        if let Some((_, led)) = &mut ten_led {
                            tenant_res.adjust_locked(led, projected);
                        }
                        true
                    }
                    None => false,
                }
            } else {
                false
            };
            if relocated {
                relocations += 1;
                delta.preempt_retried += 1;
                let target = self
                    .services
                    .platform
                    .cloud_node_at(lease.node)
                    .with_context(|| {
                        format!("resolving the relocated VM for '{}'", step.display_name)
                    })?;
                recovery.push(Event::OffloadRetried {
                    step: step.display_name.clone(),
                    node: target.name(),
                });
                continue;
            }

            // Retries exhausted, or no affordable survivor.
            if self.config.preempt_local {
                delta.declined += 1;
                delta.preempt_local += 1;
                recovery.push(Event::OffloadRecoveredLocal {
                    step: step.display_name.clone(),
                });
                return Ok(OffloadVerdict::RecoveredLocal {
                    reason: format!(
                        "cloud VM preempted {} time(s) running '{}'; \
                         retries exhausted — recovering locally",
                        delta.preempted, step.display_name
                    ),
                    events: recovery,
                });
            }
            bail!(
                "cloud VM preempted while executing '{}' and local recovery \
                 is disabled ([faults] recover_local = false)",
                step.display_name
            );
        };

        // 4. Execute remotely with retries; real bytes through the
        //    transport either way.
        let mut last_err = None;
        let mut resp_bytes = None;
        for attempt in 0..self.config.attempts.max(1) {
            match self.transport.request(&req_bytes) {
                Ok(bytes) => {
                    resp_bytes = Some(bytes);
                    break;
                }
                Err(e) => {
                    delta.failed_attempts += 1;
                    last_err = Some(e);
                    if attempt + 1 < self.config.attempts {
                        continue;
                    }
                }
            }
        }
        let Some(resp_bytes) = resp_bytes else {
            let err = last_err.unwrap();
            if self.config.local_fallback {
                delta.declined += 1;
                return Ok(OffloadVerdict::Declined {
                    reason: format!("cloud unreachable after {} attempt(s): {err:#}",
                        self.config.attempts),
                });
            }
            return Err(err.context("offload transport failed"));
        };
        let resp = OffloadResponse::decode(&resp_bytes)?;
        // Cancellation checkpoint (service mode): abort before
        // re-integrating a response for a run that was cancelled while
        // the request was in flight. Unwinding releases everything
        // held: the lease drops (slot freed), both spend reservations
        // drop (settled at zero — nothing committed for work the run
        // will never integrate), and any residents the worker already
        // published are swept by the run teardown.
        if self.config.run.cancelled() {
            bail!(
                "run {} cancelled during the offload of '{}'",
                self.config.run.id(),
                step.display_name
            );
        }
        if let Some(err) = resp.error {
            bail!("remote execution failed: {err}");
        }
        let remote_sim = Duration::from_micros(resp.remote_sim_us);
        sim += remote_sim;

        // 4a. Register the intermediates the worker kept resident:
        //     placement of the next offload in the chain reads the
        //     registry for its data-gravity term, and teardown (or a
        //     preemption of their home VM) releases them.
        if !resp.residents.is_empty() {
            let mut registry = self.residents.lock().unwrap();
            for note in &resp.residents {
                registry.insert(
                    note.uri.clone(),
                    ResidentEntry { node: note.node, bytes: note.bytes },
                );
            }
            delta.residents_published = resp.residents.len() as u64;
        }

        // 4b. Queueing delay: a VM runs one offload at a time in
        //     simulated time, so a lease granted behind `position`
        //     in-flight offloads waits for comparable work to drain.
        //     `position` reflects real lease overlap, so this term is
        //     load-dependent (deliberately: it models contention, which
        //     only exists when offloads actually overlap); workflows
        //     without oversubscribed clouds are unaffected. Positions
        //     are grant-time snapshots: if a lease ahead of this one
        //     was stolen away, the charge conservatively still counts
        //     it. For a machine-independent policy comparison use
        //     `scheduler::simulate_makespan`.
        let position = lease.position;
        // Provisioning delay rides in the same bucket: a cold VM's
        // boot time (charged at most once per warm-up by the lease)
        // is, like queueing, a transient placement artifact rather
        // than intrinsic round-trip cost — `record_costs` below must
        // not let either tip the cost gate.
        let queue_sim = remote_sim * position as u32 + lease.take_boot();
        sim += queue_sim;
        // Money: the leased (post-steal) node's price × the observed
        // reference work. Charged from the lease because prices are
        // local platform knowledge — the wire protocol stays
        // price-free and wire-compatible. Billing names the *leased*
        // VM (the reservation is what costs money); with the in-tree
        // worker the pin is always honored, so leased == executed, and
        // a legacy self-placing worker is still charged for the
        // reservation it was handed.
        let spend = lease.price * remote_sim.as_secs_f64() * node.speed;
        let billed_node = node.name();
        drop(lease);

        // 5. Downlink + re-integration.
        sim += net.transfer(resp_bytes.len() as u64);

        // 6. BundleAlways baseline also ships result data back eagerly.
        if self.config.policy == DataPolicy::BundleAlways {
            let s = self.services.mdss.synchronize_all()?;
            sim += s.sim_time;
        }

        // The cost model sees the *intrinsic* round trip (sync + wire +
        // remote compute), not the queueing delay: queueing is a
        // transient scheduling artifact, and folding it in would let a
        // momentary pile-up tip the CostBased gate into declining the
        // step — after which no new samples arrive to ever undo it.
        self.record_costs(step, sim - queue_sim, remote_sim, node.speed);

        // Commit the actual spend and release this offload's
        // projection in one ledger update, so a concurrent budget gate
        // never sees the charge and its reservation double-counted.
        // Done after the last fallible step: an error above must leave
        // the ledger's committed total in line with the stats ledger
        // (the reservation alone is released, by its Drop).
        reservation.settle(&self.ledger, spend);
        if let Some(tb) = &self.config.tenant_budget {
            tenant_res.settle(&tb.ledger, spend);
        }

        delta.offloads = 1;
        // Uplink bytes count every shipped placement attempt — a
        // preempted-and-relocated request crossed the WAN each time.
        delta.protocol_bytes = uplink_bytes + resp_bytes.len() as u64;
        delta.queued = u64::from(position > 0);
        delta.queue_sim = queue_sim;
        delta.batched_steps = req.batch.saturating_sub(1);
        delta.spend = spend;

        // Report only what the worker says it executed on — a legacy
        // worker that ignored the pin placed the work itself, and
        // fabricating the leased name here would put a VM the work
        // never ran on into the trace.
        Ok(OffloadVerdict::Executed(OffloadOutcome {
            outputs: resp.outputs,
            sim,
            remote_lines: resp.lines,
            node: resp.node,
            billed_node,
            spend,
            recovery,
        }))
    }
}

/// Home VM of a resident URI — `mdss://resident/n<idx>-<seq>/<var>`
/// (solo) or `mdss://resident/r<run>-n<idx>-<seq>/<var>` (service
/// mode) names the node whose local segment published it in its
/// second path segment. Unambiguous because run tags start with `r`
/// and never contain `-n`. `None` for URIs not in either shape
/// (foreign namespaces, legacy data URIs).
fn resident_home(uri: &Uri) -> Option<usize> {
    let mut segs = uri.as_str().strip_prefix("mdss://")?.split('/');
    let _ns = segs.next()?;
    let seg = segs.next()?;
    let tag = match seg.strip_prefix('n') {
        Some(t) => t,
        None => seg.split_once("-n")?.1,
    };
    let (idx, _) = tag.split_once('-')?;
    idx.parse().ok()
}

/// Cloud-side worker: receives packaged steps and executes them.
pub struct CloudWorker {
    engine: Engine,
    services: Arc<Services>,
    /// Uniquifier for published resident URIs: two publishes of the
    /// same variable name (loop iterations, retried requests) must
    /// never alias, so every publish burns one sequence number.
    seq: std::sync::atomic::AtomicU64,
    /// When set, reject any request that doesn't carry a valid tag
    /// (future-work §6 security).
    pub require_key: Option<SigningKey>,
}

impl CloudWorker {
    /// New worker sharing services (MDSS/platform/runtime) and the
    /// activity registry with the local side.
    pub fn new(services: Arc<Services>, registry: Arc<ActivityRegistry>) -> Arc<Self> {
        Arc::new(Self::new_inner(services, registry))
    }

    /// Unwrapped constructor (callers that need to set `require_key`).
    pub fn new_inner(services: Arc<Services>, registry: Arc<ActivityRegistry>) -> Self {
        Self {
            engine: Engine::new(registry, services.clone()).on_tier(NodeKind::Cloud),
            services,
            seq: std::sync::atomic::AtomicU64::new(0),
            require_key: None,
        }
    }

    /// Swap resident references in the inputs for their values: each
    /// `mdss://resident/…` URI is read from the cloud tier —
    /// zero-cost when the executing VM's tier already holds it fresh,
    /// a metered fetch-on-miss from the local copy otherwise (the
    /// re-materialization path after a preemption demoted it) — plus
    /// an estimated intra-cloud hop when the value is homed on a
    /// *different* VM than the pinned executor (locality-aware
    /// placement makes this the exception, not the rule; the hop is
    /// LAN time, not WAN ledger bytes). Returns the simulated time
    /// spent resolving.
    fn materialize_inputs(
        &self,
        inputs: &mut BTreeMap<String, Value>,
        pin: Option<usize>,
    ) -> Result<Duration> {
        let mdss = &self.services.mdss;
        let net = &self.services.platform.network;
        let mut sim = Duration::ZERO;
        for value in inputs.values_mut() {
            let Value::Uri(raw) = value else { continue };
            let uri = Uri::parse(raw)?;
            if uri.namespace() != "resident" {
                continue;
            }
            let (item, fetch) = mdss
                .get(NodeKind::Cloud, &uri)
                .with_context(|| format!("materializing resident input {raw}"))?;
            sim += fetch;
            if let (Some(home), Some(exec)) = (resident_home(&uri), pin) {
                if home != exec {
                    sim += net.estimate(item.payload.len() as u64);
                }
            }
            let text = std::str::from_utf8(&item.payload)
                .with_context(|| format!("resident payload for {raw} is not UTF-8"))?;
            *value = protocol::value_from_json(&crate::jsonmini::parse(text)?)
                .with_context(|| format!("decoding resident payload for {raw}"))?;
        }
        Ok(sim)
    }

    /// Execute one request.
    pub fn execute(&self, req: &OffloadRequest) -> OffloadResponse {
        if let Some(key) = &self.require_key {
            if !req.verify(key) {
                return OffloadResponse::err(
                    "authentication failed: task code signature invalid or missing".into(),
                );
            }
        }
        let step = match req.step() {
            Ok(s) => s,
            Err(e) => return OffloadResponse::err(format!("{e:#}")),
        };
        // Reconstruct the leased VM from the placement pin so compute
        // scales on exactly the node the scheduler chose (works even
        // over TCP where the worker's own platform config may differ).
        // Requests without a pin (legacy peers) or with an unusable
        // speed fall back to the remote engine's round-robin pick.
        let pin = req.node.and_then(|p| {
            (p.speed.is_finite() && p.speed > 0.0)
                .then(|| Arc::new(Node::new(NodeKind::Cloud, p.index, p.speed)))
        });
        let executed_on = pin.as_ref().map(|n| n.name());
        let pin_index = pin.as_ref().map(|n| n.index);

        // Resident references among the inputs resolve to their values
        // before execution — fetch-on-miss, charged to the response's
        // simulated time.
        let mut inputs = req.inputs.clone();
        let resolve_sim = match self.materialize_inputs(&mut inputs, pin_index) {
            Ok(d) => d,
            Err(e) => return OffloadResponse::err(format!("{e:#}")),
        };

        match self.engine.exec_subtree_on(&step, inputs, pin) {
            Ok((mut outputs, sim, lines)) => {
                // Only the declared writes travel back.
                outputs.retain(|k, _| req.writes.contains(k));
                // Publish the writes the manager classified as
                // cloud-to-cloud travel into this VM's segment and
                // replace them with references — the value bytes stay
                // resident; only the URI rides the response. Legacy
                // requests (empty plan) and pin-less placements ship
                // values exactly as before.
                let mut residents = Vec::new();
                if let Some(home) = pin_index {
                    for var in &req.resident {
                        let Some(val) = outputs.get(var) else { continue };
                        let payload = crate::jsonmini::to_string(&protocol::value_to_json(val))
                            .into_bytes();
                        let bytes = payload.len() as u64;
                        let seq =
                            self.seq.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                        // The request's run tag namespaces the URI:
                        // concurrent runs each construct their own
                        // worker-side sequence counter, so without the
                        // tag two runs would mint identical names over
                        // the shared cloud MDSS and silently read each
                        // other's intermediates. Solo requests (empty
                        // tag) keep the legacy shape byte for byte.
                        let raw = if req.run.is_empty() {
                            format!("mdss://resident/n{home}-{seq}/{var}")
                        } else {
                            format!("mdss://resident/{}-n{home}-{seq}/{var}", req.run)
                        };
                        let uri = match Uri::parse(&raw) {
                            Ok(u) => u,
                            Err(e) => {
                                return OffloadResponse::err(format!(
                                    "publishing resident '{var}': {e:#}"
                                ))
                            }
                        };
                        self.services.mdss.put(NodeKind::Cloud, &uri, payload);
                        outputs.insert(var.clone(), Value::Uri(raw.clone()));
                        residents.push(protocol::ResidentNote { uri: raw, bytes, node: home });
                    }
                }
                let mut resp = OffloadResponse::ok(outputs, sim + resolve_sim, lines);
                resp.node = executed_on;
                resp.residents = residents;
                resp
            }
            Err(e) => OffloadResponse::err(format!("{e:#}")),
        }
    }
}

impl transport::RequestHandler for CloudWorker {
    fn handle(&self, bytes: &[u8]) -> Vec<u8> {
        match OffloadRequest::decode(bytes) {
            Ok(req) => self.execute(&req).encode(),
            Err(e) => OffloadResponse::err(format!("{e:#}")).encode(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cloud::Platform;
    use crate::engine::activity::need_num;
    use crate::partitioner;
    use crate::workflow::xaml;

    fn registry() -> Arc<ActivityRegistry> {
        let mut reg = ActivityRegistry::new();
        reg.register_fn("math.square", |_c, inputs| {
            let x = need_num(inputs, "x")?;
            Ok([("y".to_string(), Value::Num(x * x))].into())
        });
        reg.register_fn("heavy.op", |c, inputs| {
            c.charge_compute(Duration::from_millis(300));
            let x = need_num(inputs, "x")?;
            Ok([("y".to_string(), Value::Num(x + 1.0))].into())
        });
        Arc::new(reg)
    }

    fn setup(policy: DataPolicy) -> (Engine, Arc<MigrationManager>) {
        let services = Services::without_runtime(Platform::paper_testbed());
        let reg = registry();
        let mgr = MigrationManager::in_proc(services.clone(), reg.clone(), policy);
        let engine = Engine::new(reg, services).with_offload(mgr.clone());
        (engine, mgr)
    }

    #[test]
    fn offload_roundtrip_via_engine() {
        let (engine, mgr) = setup(DataPolicy::Mdss);
        let wf = xaml::parse(
            r#"<Workflow>
                 <Variables><Variable Name="y"/></Variables>
                 <Sequence>
                   <InvokeActivity DisplayName="sq" Activity="math.square"
                                   In.x="6" Out.y="y" Remotable="true"/>
                   <WriteLine Text="str(y)"/>
                 </Sequence>
               </Workflow>"#,
        )
        .unwrap();
        let (part, rep) = partitioner::partition(&wf).unwrap();
        assert_eq!(rep.migration_points, 1);
        let report = engine.run(&part).unwrap();
        assert_eq!(report.lines, vec!["36"]);
        assert_eq!(report.offload_count(), 1);
        assert_eq!(mgr.stats().offloads, 1);
        assert!(mgr.stats().protocol_bytes > 0);
    }

    #[test]
    fn cloud_speedup_reflected_in_sim_time() {
        // heavy.op = 300 ms reference compute. Local: 300 ms. Cloud
        // (speed 4): 75 ms + WAN overhead (~20 ms RTT + tiny payload).
        let services = Services::without_runtime(Platform::paper_testbed());
        let reg = registry();
        let local_engine = Engine::new(reg.clone(), services.clone());
        let wf = xaml::parse(
            r#"<Workflow>
                 <Variables><Variable Name="y"/></Variables>
                 <Sequence>
                   <InvokeActivity Activity="heavy.op" In.x="1" Out.y="y" Remotable="true"/>
                 </Sequence>
               </Workflow>"#,
        )
        .unwrap();
        let (part, _) = partitioner::partition(&wf).unwrap();
        let local = local_engine.run(&part).unwrap();

        let mgr = MigrationManager::in_proc(services.clone(), reg.clone(), DataPolicy::Mdss);
        let cloud_engine = Engine::new(reg, services).with_offload(mgr);
        let cloud = cloud_engine.run(&part).unwrap();

        assert_eq!(local.sim_time, Duration::from_millis(300));
        assert!(cloud.sim_time < local.sim_time, "offload must win: {cloud:?}");
        assert!(cloud.sim_time >= Duration::from_millis(75));
    }

    #[test]
    fn remote_error_propagates() {
        let (engine, _) = setup(DataPolicy::Mdss);
        let wf = xaml::parse(
            r#"<Workflow>
                 <Variables><Variable Name="y"/></Variables>
                 <Sequence>
                   <InvokeActivity Activity="math.square" In.x="'oops'" Out.y="y" Remotable="true"/>
                 </Sequence>
               </Workflow>"#,
        )
        .unwrap();
        let (part, _) = partitioner::partition(&wf).unwrap();
        let err = format!("{:#}", engine.run(&part).unwrap_err());
        assert!(err.contains("remote execution failed"), "{err}");
    }

    #[test]
    fn mdss_policy_skips_fresh_data() {
        let (engine, mgr) = setup(DataPolicy::Mdss);
        let services = engine.services().clone();
        let uri = Uri::parse("mdss://t/data").unwrap();
        services.mdss.put(NodeKind::Local, &uri, vec![0u8; 100_000]);

        let wf = xaml::parse(
            r#"<Workflow>
                 <Variables>
                   <Variable Name="d" Init="uri('mdss://t/data')"/>
                   <Variable Name="y"/>
                 </Variables>
                 <Sequence>
                   <InvokeActivity Activity="math.square" In.x="2" In.data="d"
                                   Out.y="y" Remotable="true"/>
                 </Sequence>
               </Workflow>"#,
        )
        .unwrap();
        let (part, _) = partitioner::partition(&wf).unwrap();

        // First offload: cloud is missing the data -> sync.
        engine.run(&part).unwrap();
        assert_eq!(mgr.stats().data_syncs, 1);
        assert_eq!(mgr.stats().data_hits, 0);

        // Second offload: cloud is fresh -> task code only.
        engine.run(&part).unwrap();
        assert_eq!(mgr.stats().data_syncs, 1);
        assert_eq!(mgr.stats().data_hits, 1);
    }

    #[test]
    fn bundle_always_transfers_every_time() {
        let (engine, mgr) = setup(DataPolicy::BundleAlways);
        let services = engine.services().clone();
        let uri = Uri::parse("mdss://t/data").unwrap();
        services.mdss.put(NodeKind::Local, &uri, vec![0u8; 100_000]);

        let wf = xaml::parse(
            r#"<Workflow>
                 <Variables>
                   <Variable Name="d" Init="uri('mdss://t/data')"/>
                   <Variable Name="y"/>
                 </Variables>
                 <Sequence>
                   <InvokeActivity Activity="math.square" In.x="2" In.data="d"
                                   Out.y="y" Remotable="true"/>
                 </Sequence>
               </Workflow>"#,
        )
        .unwrap();
        let (part, _) = partitioner::partition(&wf).unwrap();
        engine.run(&part).unwrap();
        engine.run(&part).unwrap();
        // Both offloads moved the payload.
        assert_eq!(mgr.stats().data_syncs, 2);
        assert_eq!(mgr.stats().data_hits, 0);
    }

    #[test]
    fn parallel_remotable_steps_offload_concurrently() {
        // Fig 9b through the real migration manager: 4 parallel
        // remotable steps, each 200 ms reference -> sim time must be
        // ~one cloud step (50 ms) + WAN, not 4x.
        let services = Services::without_runtime(Platform::paper_testbed());
        let mut reg = ActivityRegistry::new();
        reg.register_fn("slow", |c, inputs| {
            c.charge_compute(Duration::from_millis(200));
            let x = need_num(inputs, "x")?;
            Ok([("y".to_string(), Value::Num(x))].into())
        });
        let reg = Arc::new(reg);
        let mgr = MigrationManager::in_proc(services.clone(), reg.clone(), DataPolicy::Mdss);
        let engine = Engine::new(reg, services).with_offload(mgr);
        let wf = xaml::parse(
            r#"<Workflow>
                 <Workflow.Variables>
                   <Variable Name="a"/><Variable Name="b"/>
                   <Variable Name="c"/><Variable Name="d"/>
                 </Workflow.Variables>
                 <Parallel>
                   <InvokeActivity Activity="slow" In.x="1" Out.y="a" Remotable="true"/>
                   <InvokeActivity Activity="slow" In.x="2" Out.y="b" Remotable="true"/>
                   <InvokeActivity Activity="slow" In.x="3" Out.y="c" Remotable="true"/>
                   <InvokeActivity Activity="slow" In.x="4" Out.y="d" Remotable="true"/>
                 </Parallel>
               </Workflow>"#,
        )
        .unwrap();
        let (part, _) = partitioner::partition(&wf).unwrap();
        let report = engine.run(&part).unwrap();
        assert_eq!(report.offload_count(), 4);
        // One offload ≈ 50 ms remote + ~20 ms WAN; sequential would be
        // ≥ 280 ms. Parallel must stay well under 2x one offload.
        assert!(
            report.sim_time < Duration::from_millis(140),
            "parallel offloads must overlap: {:?}",
            report.sim_time
        );
    }

    #[test]
    fn cost_record_ewma_adapts_to_drift() {
        let ms = Duration::from_millis;
        let mut rec = CostRecord::default();
        assert!(rec.remote_estimate().is_none());
        assert!(rec.work_estimate().is_none());
        rec.observe(ms(100), ms(200), ms(100));
        assert!(rec.remote_obs_us >= rec.local_est_us, "first regime: remote loses");
        // The regime changes (cloud sped up / data became fresh): the
        // seed's single-sample record would stay locked on the first
        // observation; the EWMA converges.
        for _ in 0..20 {
            rec.observe(ms(100), ms(10), ms(40));
        }
        assert!(rec.remote_obs_us < rec.local_est_us, "EWMA must adapt: {rec:?}");
        assert_eq!(rec.samples, 21);
        let est = rec.remote_estimate().unwrap();
        assert!(est > ms(5) && est < ms(50), "estimate near new regime: {est:?}");
        let work = rec.work_estimate().unwrap();
        assert!(work > ms(35) && work < ms(100), "work EWMA converges: {work:?}");
    }

    #[test]
    fn batched_offload_single_round_trip_same_results() {
        let chain_wf = || {
            xaml::parse(
                r#"<Workflow>
                     <Workflow.Variables>
                       <Variable Name="a"/><Variable Name="b"/><Variable Name="c"/>
                     </Workflow.Variables>
                     <Sequence>
                       <InvokeActivity DisplayName="s1" Activity="math.square" In.x="2"
                                       Out.y="a" Remotable="true"/>
                       <InvokeActivity DisplayName="s2" Activity="math.square" In.x="a"
                                       Out.y="b" Remotable="true"/>
                       <InvokeActivity DisplayName="s3" Activity="math.square" In.x="b"
                                       Out.y="c" Remotable="true"/>
                       <WriteLine Text="str(c)"/>
                     </Sequence>
                   </Workflow>"#,
            )
            .unwrap()
        };

        let (engine, mgr) = setup(DataPolicy::Mdss);
        let (plain, rep) = partitioner::partition(&chain_wf()).unwrap();
        assert_eq!(rep.migration_points, 3);
        let r1 = engine.run(&plain).unwrap();
        assert_eq!(r1.lines, vec!["256"]);
        assert_eq!(r1.offload_count(), 3);
        assert_eq!(mgr.stats().batched_steps, 0);

        let (engine2, mgr2) = setup(DataPolicy::Mdss);
        let (fused, rep) = partitioner::partition_with(
            &chain_wf(),
            partitioner::PartitionOptions { batch: true, ..Default::default() },
        )
        .unwrap();
        assert_eq!(rep.migration_points, 1);
        assert_eq!(rep.batched_steps, 3);
        let r2 = engine2.run(&fused).unwrap();
        assert_eq!(r2.lines, vec!["256"], "batching must not change results");
        assert_eq!(r2.offload_count(), 1, "one round trip for the whole run");
        assert_eq!(mgr2.stats().offloads, 1);
        assert_eq!(mgr2.stats().batched_steps, 2);
        assert!(
            r2.sim_time < r1.sim_time,
            "amortizing the WAN must win: batched {:?} vs unbatched {:?}",
            r2.sim_time,
            r1.sim_time
        );
    }

    #[test]
    fn stale_cost_verdicts_reprobe_without_discarding_the_ewma() {
        // WAN-dominated step on a high-latency link: the first
        // observation teaches the cost gate that remote loses, and
        // without re-probing that verdict is frozen forever (no new
        // samples ever arrive to undo it).
        let run_n = |decay: Option<u64>, runs: usize| {
            let platform = Platform::new(crate::cloud::PlatformConfig {
                wan_latency: Duration::from_millis(200),
                ..Default::default()
            })
            .unwrap();
            let services = Services::without_runtime(platform);
            let reg = registry();
            let mut cfg = ManagerConfig::new(DataPolicy::Mdss);
            cfg.decision = Decision::CostBased;
            cfg.decay_after = decay;
            let mgr = MigrationManager::in_proc_with_config(services.clone(), reg.clone(), cfg);
            let engine = Engine::new(reg, services).with_offload(mgr.clone());
            let wf = xaml::parse(
                r#"<Workflow>
                     <Variables><Variable Name="y"/></Variables>
                     <Sequence>
                       <InvokeActivity DisplayName="tiny" Activity="math.square" In.x="3"
                                       Out.y="y" Remotable="true"/>
                     </Sequence>
                   </Workflow>"#,
            )
            .unwrap();
            let (part, _) = partitioner::partition(&wf).unwrap();
            for _ in 0..runs {
                engine.run(&part).unwrap();
            }
            let samples = mgr.history.lock().unwrap().records["tiny"].samples;
            (mgr.stats(), samples)
        };
        let (frozen, frozen_samples) = run_n(None, 4);
        assert_eq!(
            (frozen.offloads, frozen.declined),
            (1, 3),
            "without re-probing the stale estimate gates forever"
        );
        assert_eq!(frozen_samples, 1);
        // decay_after = 2: runs 2 and 3 decline (staleness 1, then 2);
        // run 4 crosses the window (staleness 3 > 2), so the gate
        // admits a probe — the step is re-observed and the fresh
        // sample BLENDS into the record instead of re-seeding it.
        let (probed, probed_samples) = run_n(Some(2), 4);
        assert_eq!(
            (probed.offloads, probed.declined),
            (2, 2),
            "a stale decline must re-probe"
        );
        assert_eq!(
            probed_samples, 2,
            "the probe's observation must extend the EWMA history, not restart it"
        );
    }

    #[test]
    fn dataflow_engine_offloads_independent_siblings_concurrently() {
        // Two independent remotable steps in a Sequence: dataflow mode
        // runs them as one wavefront, so simulated time is one round
        // trip (the critical path), not two — with identical results.
        let wf = xaml::parse(
            r#"<Workflow>
                 <Variables><Variable Name="a"/><Variable Name="b"/></Variables>
                 <Sequence>
                   <InvokeActivity DisplayName="h1" Activity="heavy.op" In.x="1"
                                   Out.y="a" Remotable="true"/>
                   <InvokeActivity DisplayName="h2" Activity="heavy.op" In.x="2"
                                   Out.y="b" Remotable="true"/>
                   <WriteLine Text="str(a + b)"/>
                 </Sequence>
               </Workflow>"#,
        )
        .unwrap();
        let (part, _) = partitioner::partition(&wf).unwrap();

        let (seq_engine, _) = setup(DataPolicy::Mdss);
        let seq = seq_engine.run(&part).unwrap();

        let services = Services::without_runtime(Platform::paper_testbed());
        let reg = registry();
        let mgr = MigrationManager::in_proc(services.clone(), reg.clone(), DataPolicy::Mdss);
        let df_engine = Engine::new(reg, services)
            .with_offload(mgr.clone())
            .with_dataflow(true);
        let df = df_engine.run(&part).unwrap();

        assert_eq!(df.lines, seq.lines, "dataflow must not change results");
        assert_eq!(df.lines, vec!["5"]);
        assert_eq!(df.offload_count(), 2);
        assert_eq!(mgr.stats().offloads, 2);
        // heavy.op = 300 ms reference -> 75 ms on the x4 cloud + WAN
        // per trip. Sequential sums two trips; the dataflow critical
        // path is the max of the two.
        assert!(
            df.sim_time < seq.sim_time,
            "concurrent offloads must overlap: {:?} vs {:?}",
            df.sim_time,
            seq.sim_time
        );
    }

    #[test]
    fn zero_cloud_platform_declines_instead_of_panicking() {
        let platform = Platform::new(crate::cloud::PlatformConfig {
            tiers: vec![],
            ..Default::default()
        })
        .unwrap();
        let services = Services::without_runtime(platform);
        let reg = registry();
        let mgr = MigrationManager::in_proc(services.clone(), reg.clone(), DataPolicy::Mdss);
        let engine = Engine::new(reg, services).with_offload(mgr.clone());
        let wf = xaml::parse(
            r#"<Workflow>
                 <Variables><Variable Name="y"/></Variables>
                 <Sequence>
                   <InvokeActivity Activity="math.square" In.x="5" Out.y="y" Remotable="true"/>
                   <WriteLine Text="str(y)"/>
                 </Sequence>
               </Workflow>"#,
        )
        .unwrap();
        let (part, _) = partitioner::partition(&wf).unwrap();
        let report = engine.run(&part).unwrap();
        assert!(report.lines.iter().any(|l| l == "25"), "{:?}", report.lines);
        assert!(report
            .events
            .iter()
            .any(|e| matches!(e, crate::engine::Event::LocalExecution { .. })));
        assert_eq!(mgr.stats().declined, 1);
        assert_eq!(mgr.stats().offloads, 0);
    }

    #[test]
    fn resident_home_parses_solo_and_run_scoped_uris() {
        let h = |s: &str| resident_home(&Uri::parse(s).unwrap());
        assert_eq!(h("mdss://resident/n3-7/x"), Some(3));
        assert_eq!(h("mdss://resident/r12-n5-0/y"), Some(5));
        assert_eq!(h("mdss://data/foo"), None);
        assert_eq!(h("mdss://t/new"), None);
    }

    #[test]
    fn concurrent_runs_never_collide_on_resident_uris() {
        // Regression: each run's cloud worker mints resident URIs from
        // its own sequence counter starting at zero, so two runs over
        // one shared cloud MDSS used to publish identical
        // `mdss://resident/n<node>-0/<var>` names and silently read
        // each other's intermediates. The run tag namespaces them.
        use crate::workflow::StepKind;
        let services = Services::without_runtime(Platform::paper_testbed());
        let reg = registry();
        let mk = |id: u64, tenant: &str| {
            let mut cfg = ManagerConfig::new(DataPolicy::Mdss);
            cfg.run = RunContext::service(id, tenant);
            let worker = CloudWorker::new(services.clone(), reg.clone());
            MigrationManager::with_config(
                services.clone(),
                Box::new(InProcTransport::new(worker)),
                cfg,
            )
        };
        let m1 = mk(1, "a");
        let m2 = mk(2, "b");
        let step = Step::new(
            "sq",
            StepKind::InvokeActivity {
                activity: "math.square".into(),
                inputs: vec![("x".into(), "x".into())],
                outputs: vec![("y".into(), "y".into())],
            },
        )
        .remotable();
        let offload = |m: &MigrationManager, x: f64| {
            let verdict = m
                .offload_with(
                    &step,
                    [("x".to_string(), Value::Num(x))].into(),
                    &["y".to_string()],
                    &["y".to_string()],
                )
                .unwrap();
            match verdict {
                OffloadVerdict::Executed(o) => match o.outputs.get("y") {
                    Some(Value::Uri(u)) => u.clone(),
                    other => panic!("expected a resident reference, got {other:?}"),
                },
                other => panic!("expected an executed offload, got {other:?}"),
            }
        };
        let u1 = offload(&m1, 2.0);
        let u2 = offload(&m2, 3.0);
        assert_ne!(u1, u2, "concurrent runs minted the same resident URI");
        assert!(u1.starts_with("mdss://resident/r1-n"), "{u1}");
        assert!(u2.starts_with("mdss://resident/r2-n"), "{u2}");
        // Both payloads coexist on the shared cloud MDSS.
        let p1 = Uri::parse(&u1).unwrap();
        let p2 = Uri::parse(&u2).unwrap();
        assert!(services.mdss.peek(NodeKind::Cloud, &p1).is_some());
        assert!(services.mdss.peek(NodeKind::Cloud, &p2).is_some());
        // Teardown is run-scoped: run 1's sweep must not touch run 2.
        m1.run_teardown().unwrap();
        assert_eq!(m1.leaked_residents(), 0);
        assert!(services.mdss.peek(NodeKind::Cloud, &p1).is_none());
        assert!(
            services.mdss.peek(NodeKind::Cloud, &p2).is_some(),
            "run 1's teardown swept run 2's resident"
        );
        m2.run_teardown().unwrap();
        assert_eq!(m2.leaked_residents(), 0);
        assert!(services.mdss.peek(NodeKind::Cloud, &p2).is_none());
    }

    #[test]
    fn cancellation_mid_offload_releases_lease_reservation_and_residents() {
        // The run is cancelled while its request executes remotely
        // (the activity flips the flag, so the cancellation lands
        // exactly between uplink and re-integration). The offload must
        // fail without committing anything: lease released, both
        // ledger totals at zero, and the resident the worker already
        // published swept by teardown.
        use crate::workflow::StepKind;
        let services = Services::without_runtime(Platform::paper_testbed());
        let ctx = RunContext::service(7, "t");
        let mut reg = ActivityRegistry::new();
        let cancel_ctx = ctx.clone();
        reg.register_fn("sq.cancelling", move |_c, inputs| {
            cancel_ctx.cancel();
            let x = need_num(inputs, "x")?;
            Ok([("y".to_string(), Value::Num(x * x))].into())
        });
        let reg = Arc::new(reg);
        let mut cfg = ManagerConfig::new(DataPolicy::Mdss);
        cfg.run = ctx.clone();
        cfg.budget = Some(10.0);
        let mgr = MigrationManager::in_proc_with_config(services.clone(), reg, cfg);
        let step = Step::new(
            "sq",
            StepKind::InvokeActivity {
                activity: "sq.cancelling".into(),
                inputs: vec![("x".into(), "x".into())],
                outputs: vec![("y".into(), "y".into())],
            },
        )
        .remotable();
        let err = mgr
            .offload_with(
                &step,
                [("x".to_string(), Value::Num(3.0))].into(),
                &["y".to_string()],
                &["y".to_string()],
            )
            .unwrap_err();
        assert!(format!("{err:#}").contains("cancelled"), "{err:#}");
        // The reservation settled at zero: nothing committed, nothing
        // still reserved, no spend recorded.
        assert_eq!(mgr.ledger(), (0.0, 0.0));
        assert_eq!(mgr.stats().spend, 0.0);
        assert_eq!(mgr.stats().offloads, 0);
        // The lease was released: every VM previews idle (hold each
        // lease while probing so a leaked slot cannot hide behind an
        // idle neighbour).
        let mut held = Vec::new();
        for _ in 0..services.platform.cloud_size() {
            let (p, l) = services
                .platform
                .cloud_lease_preview_transfer(None, Objective::Time, &[])
                .unwrap();
            assert_eq!(
                (p.active, p.wait),
                (0, Duration::ZERO),
                "a cancelled offload leaked its lease"
            );
            held.push(l);
        }
        drop(held);
        // The worker's published resident was never registered (the
        // checkpoint fires before registration) and the run-scoped
        // sweep clears it from the store.
        mgr.run_teardown().unwrap();
        assert_eq!(mgr.leaked_residents(), 0);
        assert_eq!(services.mdss.count(NodeKind::Cloud), 0);
        // Fresh offloads from this manager stay refused.
        assert!(mgr
            .offload_with(&step, [("x".to_string(), Value::Num(2.0))].into(), &[], &[])
            .is_err());
    }

    #[test]
    fn tcp_transport_end_to_end() {
        let services = Services::without_runtime(Platform::paper_testbed());
        let reg = registry();
        let worker = CloudWorker::new(services.clone(), reg.clone());
        let addr = serve_tcp(worker).unwrap();
        let transport = TcpTransport::connect(addr).unwrap();
        let mgr = MigrationManager::new(services.clone(), Box::new(transport), DataPolicy::Mdss);
        let engine = Engine::new(reg, services).with_offload(mgr);

        let wf = xaml::parse(
            r#"<Workflow>
                 <Variables><Variable Name="y"/></Variables>
                 <Sequence>
                   <InvokeActivity Activity="math.square" In.x="9" Out.y="y" Remotable="true"/>
                   <WriteLine Text="str(y)"/>
                 </Sequence>
               </Workflow>"#,
        )
        .unwrap();
        let (part, _) = partitioner::partition(&wf).unwrap();
        let report = engine.run(&part).unwrap();
        assert_eq!(report.lines, vec!["81"]);
    }
}
