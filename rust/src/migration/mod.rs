//! The migration manager (paper §3.3) — both sides.
//!
//! **Local side** ([`MigrationManager`], plugged into the engine as its
//! [`OffloadHandler`]): when the engine suspends at a migration point,
//! the manager
//!
//! 1. checks MDSS freshness for every data URI the step references —
//!    fresh cloud copies mean only task code crosses the wire, stale or
//!    missing ones are synchronized first (paper Fig 10);
//! 2. packages the step (task-code XML + input values) and sends it
//!    over the [`transport::Transport`], charging the uplink to the
//!    simulated WAN;
//! 3. receives the response, charges the downlink, and hands the
//!    outputs back to the engine for re-integration.
//!
//! **Cloud side** ([`CloudWorker`], a [`transport::RequestHandler`]):
//! deserializes the step, executes it on a cloud node with a remote
//! engine (offloading disabled — Property 3 guarantees no nesting),
//! and returns outputs + the remote simulated time.

pub mod protocol;
pub mod security;
pub mod transport;

pub use protocol::{OffloadRequest, OffloadResponse};
pub use security::SigningKey;
pub use transport::{serve_tcp, InProcTransport, TcpTransport, Transport};

use std::collections::BTreeMap;
use std::sync::{Arc, Mutex};
use std::time::Duration;

use anyhow::{bail, Result};

use crate::cloud::NodeKind;
use crate::engine::{
    ActivityRegistry, Engine, OffloadHandler, OffloadOutcome, OffloadVerdict, Services,
};
use crate::expr::Value;
use crate::mdss::{CloudState, Uri};
use crate::workflow::Step;

/// Data-placement policy (E4 ablation).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DataPolicy {
    /// MDSS enabled (the paper's system): transfer application data
    /// only when the cloud copy is stale or missing.
    Mdss,
    /// MDSS disabled baseline: bundle all referenced application data
    /// with every offload and eagerly ship results back.
    BundleAlways,
}

/// Offload-decision policy (E8 ablation; the paper offloads every
/// remotable step unconditionally).
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Decision {
    /// Paper behaviour: always offload remotable steps.
    Always,
    /// Cost model: offload only when the estimated remote round trip
    /// beats the estimated local execution (per step name, from the
    /// history of observed costs; first sighting always offloads).
    CostBased,
}

/// Fault-handling configuration for the offload path.
#[derive(Debug, Clone)]
pub struct ManagerConfig {
    pub policy: DataPolicy,
    pub decision: Decision,
    /// Transport attempts per offload (>= 1).
    pub attempts: usize,
    /// After all attempts fail, decline so the engine runs the step
    /// locally instead of failing the workflow.
    pub local_fallback: bool,
    /// Sign requests with this key (worker must hold the same key).
    pub signing: Option<SigningKey>,
}

impl ManagerConfig {
    /// Paper defaults: MDSS placement, always offload, one attempt,
    /// no fallback, no signing.
    pub fn new(policy: DataPolicy) -> Self {
        Self {
            policy,
            decision: Decision::Always,
            attempts: 1,
            local_fallback: false,
            signing: None,
        }
    }
}

/// Cumulative migration statistics.
#[derive(Debug, Clone, Copy, Default)]
pub struct MigrationStats {
    pub offloads: u64,
    /// Protocol bytes (task code + values), excluding MDSS data.
    pub protocol_bytes: u64,
    /// Offloads where all data URIs were already fresh on the cloud.
    pub data_hits: u64,
    /// Offloads that required at least one data synchronization.
    pub data_syncs: u64,
    /// Simulated time spent in pre-offload data synchronization.
    pub sync_sim: Duration,
    /// Transport attempts that failed (retried or fallen back).
    pub failed_attempts: u64,
    /// Offloads declined by the cost model or by fallback.
    pub declined: u64,
}

/// Per-step-name cost history for [`Decision::CostBased`].
#[derive(Debug, Clone, Copy, Default)]
struct CostRecord {
    /// Estimated local execution time (reference compute).
    local_est: Duration,
    /// Observed remote round-trip time.
    remote_obs: Duration,
    seen: bool,
}

/// Local-side migration manager.
pub struct MigrationManager {
    services: Arc<Services>,
    transport: Box<dyn Transport>,
    config: ManagerConfig,
    stats: Mutex<MigrationStats>,
    history: Mutex<BTreeMap<String, CostRecord>>,
}

impl MigrationManager {
    /// New manager over a transport with paper-default behaviour.
    pub fn new(
        services: Arc<Services>,
        transport: Box<dyn Transport>,
        policy: DataPolicy,
    ) -> Arc<Self> {
        Self::with_config(services, transport, ManagerConfig::new(policy))
    }

    /// New manager with explicit configuration.
    pub fn with_config(
        services: Arc<Services>,
        transport: Box<dyn Transport>,
        config: ManagerConfig,
    ) -> Arc<Self> {
        Arc::new(Self {
            services,
            transport,
            config,
            stats: Mutex::new(Default::default()),
            history: Mutex::new(BTreeMap::new()),
        })
    }

    /// Convenience: manager + in-process cloud worker pair sharing the
    /// same services and registry.
    pub fn in_proc(
        services: Arc<Services>,
        registry: Arc<ActivityRegistry>,
        policy: DataPolicy,
    ) -> Arc<Self> {
        let worker = CloudWorker::new(services.clone(), registry);
        Self::new(services, Box::new(InProcTransport::new(worker)), policy)
    }

    /// In-process pair with explicit configuration. The worker gets
    /// the same signing key when one is configured.
    pub fn in_proc_with_config(
        services: Arc<Services>,
        registry: Arc<ActivityRegistry>,
        config: ManagerConfig,
    ) -> Arc<Self> {
        let mut worker = CloudWorker::new_inner(services.clone(), registry);
        worker.require_key = config.signing.clone();
        Self::with_config(
            services,
            Box::new(InProcTransport::new(Arc::new(worker))),
            config,
        )
    }

    /// Cumulative statistics.
    pub fn stats(&self) -> MigrationStats {
        *self.stats.lock().unwrap()
    }

    /// URIs referenced by the input values.
    fn data_uris(inputs: &BTreeMap<String, Value>) -> Result<Vec<Uri>> {
        inputs
            .values()
            .filter_map(|v| match v {
                Value::Uri(u) => Some(Uri::parse(u)),
                _ => None,
            })
            .collect()
    }

    /// Fig-10 data placement: returns the simulated time spent moving
    /// application data before the step itself is offloaded.
    fn place_data(&self, uris: &[Uri], stats: &mut MigrationStats) -> Result<Duration> {
        let mdss = &self.services.mdss;
        let mut sim = Duration::ZERO;
        let mut synced_any = false;
        for uri in uris {
            let must_sync = match self.config.policy {
                DataPolicy::Mdss => !matches!(
                    mdss.cloud_state(uri),
                    CloudState::Fresh | CloudState::Unknown
                ),
                DataPolicy::BundleAlways => true,
            };
            if must_sync {
                match self.config.policy {
                    DataPolicy::Mdss => {
                        let s = mdss.synchronize(uri)?;
                        sim += s.sim_time;
                        synced_any = true;
                    }
                    DataPolicy::BundleAlways => {
                        // Bundle the payload with the request even when
                        // the cloud already has it (version preserved,
                        // so results are not spuriously shipped back).
                        if let Some(item) = mdss.peek(NodeKind::Local, uri) {
                            sim += self
                                .services
                                .platform
                                .network
                                .transfer(item.payload.len() as u64);
                            mdss.replicate(NodeKind::Local, NodeKind::Cloud, uri)?;
                            synced_any = true;
                        }
                    }
                }
            }
        }
        if synced_any {
            stats.data_syncs += 1;
        } else if !uris.is_empty() {
            stats.data_hits += 1;
        }
        Ok(sim)
    }
}

impl MigrationManager {
    /// Cost-model gate: should this step be offloaded at all?
    fn should_offload(&self, step: &Step) -> Option<String> {
        if self.config.decision == Decision::Always {
            return None;
        }
        let history = self.history.lock().unwrap();
        match history.get(&step.display_name) {
            Some(rec) if rec.seen && rec.remote_obs >= rec.local_est => Some(format!(
                "cost model: remote {:.0}ms >= local {:.0}ms for '{}'",
                rec.remote_obs.as_secs_f64() * 1e3,
                rec.local_est.as_secs_f64() * 1e3,
                step.display_name
            )),
            _ => None,
        }
    }

    /// Record observed costs for the cost model. The local estimate is
    /// recovered from the remote compute time (remote ran at
    /// `cloud_speed`, so local ≈ remote_compute × cloud_speed).
    fn record_costs(&self, step: &Step, remote_total: Duration, remote_compute: Duration) {
        let local_est = Duration::from_secs_f64(
            remote_compute.as_secs_f64() * self.services.platform.config.cloud_speed,
        );
        self.history.lock().unwrap().insert(
            step.display_name.clone(),
            CostRecord { local_est, remote_obs: remote_total, seen: true },
        );
    }
}

impl OffloadHandler for MigrationManager {
    fn offload(
        &self,
        step: &Step,
        inputs: BTreeMap<String, Value>,
        writes: &[String],
    ) -> Result<OffloadVerdict> {
        // 0. Cost-model gate (E8; the paper always offloads).
        if let Some(reason) = self.should_offload(step) {
            self.stats.lock().unwrap().declined += 1;
            return Ok(OffloadVerdict::Declined { reason });
        }

        let net = &self.services.platform.network;
        let mut stats_delta = MigrationStats::default();
        let mut sim = Duration::ZERO;

        // 1. Data placement (MDSS freshness / bundling).
        let uris = Self::data_uris(&inputs)?;
        let sync_sim = self.place_data(&uris, &mut stats_delta)?;
        stats_delta.sync_sim = sync_sim;
        sim += sync_sim;

        // 2. Package (+ sign) + uplink.
        let mut req = OffloadRequest::package(step, inputs, writes);
        if let Some(key) = &self.config.signing {
            req.sign(key);
        }
        let req_bytes = req.encode();
        sim += net.transfer(req_bytes.len() as u64);

        // 3. Remote execution with retries; real bytes through the
        //    transport either way.
        let mut last_err = None;
        let mut resp_bytes = None;
        for attempt in 0..self.config.attempts.max(1) {
            match self.transport.request(&req_bytes) {
                Ok(bytes) => {
                    resp_bytes = Some(bytes);
                    break;
                }
                Err(e) => {
                    self.stats.lock().unwrap().failed_attempts += 1;
                    last_err = Some(e);
                    if attempt + 1 < self.config.attempts {
                        continue;
                    }
                }
            }
        }
        let Some(resp_bytes) = resp_bytes else {
            let err = last_err.unwrap();
            if self.config.local_fallback {
                self.stats.lock().unwrap().declined += 1;
                return Ok(OffloadVerdict::Declined {
                    reason: format!("cloud unreachable after {} attempt(s): {err:#}",
                        self.config.attempts),
                });
            }
            return Err(err.context("offload transport failed"));
        };
        let resp = OffloadResponse::decode(&resp_bytes)?;
        if let Some(err) = resp.error {
            bail!("remote execution failed: {err}");
        }
        let remote_sim = Duration::from_micros(resp.remote_sim_us);
        sim += remote_sim;

        // 4. Downlink + re-integration.
        sim += net.transfer(resp_bytes.len() as u64);

        // 5. BundleAlways baseline also ships result data back eagerly.
        if self.config.policy == DataPolicy::BundleAlways {
            let s = self.services.mdss.synchronize_all()?;
            sim += s.sim_time;
        }

        self.record_costs(step, sim, remote_sim);

        stats_delta.offloads = 1;
        stats_delta.protocol_bytes = (req_bytes.len() + resp_bytes.len()) as u64;
        {
            let mut st = self.stats.lock().unwrap();
            st.offloads += stats_delta.offloads;
            st.protocol_bytes += stats_delta.protocol_bytes;
            st.data_hits += stats_delta.data_hits;
            st.data_syncs += stats_delta.data_syncs;
            st.sync_sim += stats_delta.sync_sim;
        }

        Ok(OffloadVerdict::Executed(OffloadOutcome {
            outputs: resp.outputs,
            sim,
            remote_lines: resp.lines,
        }))
    }
}

/// Cloud-side worker: receives packaged steps and executes them.
pub struct CloudWorker {
    engine: Engine,
    /// When set, reject any request that doesn't carry a valid tag
    /// (future-work §6 security).
    pub require_key: Option<SigningKey>,
}

impl CloudWorker {
    /// New worker sharing services (MDSS/platform/runtime) and the
    /// activity registry with the local side.
    pub fn new(services: Arc<Services>, registry: Arc<ActivityRegistry>) -> Arc<Self> {
        Arc::new(Self::new_inner(services, registry))
    }

    /// Unwrapped constructor (callers that need to set `require_key`).
    pub fn new_inner(services: Arc<Services>, registry: Arc<ActivityRegistry>) -> Self {
        Self {
            engine: Engine::new(registry, services).on_tier(NodeKind::Cloud),
            require_key: None,
        }
    }

    /// Execute one request.
    pub fn execute(&self, req: &OffloadRequest) -> OffloadResponse {
        if let Some(key) = &self.require_key {
            if !req.verify(key) {
                return OffloadResponse::err(
                    "authentication failed: task code signature invalid or missing".into(),
                );
            }
        }
        let step = match req.step() {
            Ok(s) => s,
            Err(e) => return OffloadResponse::err(format!("{e:#}")),
        };
        match self.engine.exec_subtree(&step, req.inputs.clone()) {
            Ok((mut outputs, sim, lines)) => {
                // Only the declared writes travel back.
                outputs.retain(|k, _| req.writes.contains(k));
                OffloadResponse::ok(outputs, sim, lines)
            }
            Err(e) => OffloadResponse::err(format!("{e:#}")),
        }
    }
}

impl transport::RequestHandler for CloudWorker {
    fn handle(&self, bytes: &[u8]) -> Vec<u8> {
        match OffloadRequest::decode(bytes) {
            Ok(req) => self.execute(&req).encode(),
            Err(e) => OffloadResponse::err(format!("{e:#}")).encode(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cloud::Platform;
    use crate::engine::activity::need_num;
    use crate::partitioner;
    use crate::workflow::xaml;

    fn registry() -> Arc<ActivityRegistry> {
        let mut reg = ActivityRegistry::new();
        reg.register_fn("math.square", |_c, inputs| {
            let x = need_num(inputs, "x")?;
            Ok([("y".to_string(), Value::Num(x * x))].into())
        });
        reg.register_fn("heavy.op", |c, inputs| {
            c.charge_compute(Duration::from_millis(300));
            let x = need_num(inputs, "x")?;
            Ok([("y".to_string(), Value::Num(x + 1.0))].into())
        });
        Arc::new(reg)
    }

    fn setup(policy: DataPolicy) -> (Engine, Arc<MigrationManager>) {
        let services = Services::without_runtime(Platform::paper_testbed());
        let reg = registry();
        let mgr = MigrationManager::in_proc(services.clone(), reg.clone(), policy);
        let engine = Engine::new(reg, services).with_offload(mgr.clone());
        (engine, mgr)
    }

    #[test]
    fn offload_roundtrip_via_engine() {
        let (engine, mgr) = setup(DataPolicy::Mdss);
        let wf = xaml::parse(
            r#"<Workflow>
                 <Variables><Variable Name="y"/></Variables>
                 <Sequence>
                   <InvokeActivity DisplayName="sq" Activity="math.square"
                                   In.x="6" Out.y="y" Remotable="true"/>
                   <WriteLine Text="str(y)"/>
                 </Sequence>
               </Workflow>"#,
        )
        .unwrap();
        let (part, rep) = partitioner::partition(&wf).unwrap();
        assert_eq!(rep.migration_points, 1);
        let report = engine.run(&part).unwrap();
        assert_eq!(report.lines, vec!["36"]);
        assert_eq!(report.offload_count(), 1);
        assert_eq!(mgr.stats().offloads, 1);
        assert!(mgr.stats().protocol_bytes > 0);
    }

    #[test]
    fn cloud_speedup_reflected_in_sim_time() {
        // heavy.op = 300 ms reference compute. Local: 300 ms. Cloud
        // (speed 4): 75 ms + WAN overhead (~20 ms RTT + tiny payload).
        let services = Services::without_runtime(Platform::paper_testbed());
        let reg = registry();
        let local_engine = Engine::new(reg.clone(), services.clone());
        let wf = xaml::parse(
            r#"<Workflow>
                 <Variables><Variable Name="y"/></Variables>
                 <Sequence>
                   <InvokeActivity Activity="heavy.op" In.x="1" Out.y="y" Remotable="true"/>
                 </Sequence>
               </Workflow>"#,
        )
        .unwrap();
        let (part, _) = partitioner::partition(&wf).unwrap();
        let local = local_engine.run(&part).unwrap();

        let mgr = MigrationManager::in_proc(services.clone(), reg.clone(), DataPolicy::Mdss);
        let cloud_engine = Engine::new(reg, services).with_offload(mgr);
        let cloud = cloud_engine.run(&part).unwrap();

        assert_eq!(local.sim_time, Duration::from_millis(300));
        assert!(cloud.sim_time < local.sim_time, "offload must win: {cloud:?}");
        assert!(cloud.sim_time >= Duration::from_millis(75));
    }

    #[test]
    fn remote_error_propagates() {
        let (engine, _) = setup(DataPolicy::Mdss);
        let wf = xaml::parse(
            r#"<Workflow>
                 <Variables><Variable Name="y"/></Variables>
                 <Sequence>
                   <InvokeActivity Activity="math.square" In.x="'oops'" Out.y="y" Remotable="true"/>
                 </Sequence>
               </Workflow>"#,
        )
        .unwrap();
        let (part, _) = partitioner::partition(&wf).unwrap();
        let err = format!("{:#}", engine.run(&part).unwrap_err());
        assert!(err.contains("remote execution failed"), "{err}");
    }

    #[test]
    fn mdss_policy_skips_fresh_data() {
        let (engine, mgr) = setup(DataPolicy::Mdss);
        let services = engine.services().clone();
        let uri = Uri::parse("mdss://t/data").unwrap();
        services.mdss.put(NodeKind::Local, &uri, vec![0u8; 100_000]);

        let wf = xaml::parse(
            r#"<Workflow>
                 <Variables>
                   <Variable Name="d" Init="uri('mdss://t/data')"/>
                   <Variable Name="y"/>
                 </Variables>
                 <Sequence>
                   <InvokeActivity Activity="math.square" In.x="2" In.data="d"
                                   Out.y="y" Remotable="true"/>
                 </Sequence>
               </Workflow>"#,
        )
        .unwrap();
        let (part, _) = partitioner::partition(&wf).unwrap();

        // First offload: cloud is missing the data -> sync.
        engine.run(&part).unwrap();
        assert_eq!(mgr.stats().data_syncs, 1);
        assert_eq!(mgr.stats().data_hits, 0);

        // Second offload: cloud is fresh -> task code only.
        engine.run(&part).unwrap();
        assert_eq!(mgr.stats().data_syncs, 1);
        assert_eq!(mgr.stats().data_hits, 1);
    }

    #[test]
    fn bundle_always_transfers_every_time() {
        let (engine, mgr) = setup(DataPolicy::BundleAlways);
        let services = engine.services().clone();
        let uri = Uri::parse("mdss://t/data").unwrap();
        services.mdss.put(NodeKind::Local, &uri, vec![0u8; 100_000]);

        let wf = xaml::parse(
            r#"<Workflow>
                 <Variables>
                   <Variable Name="d" Init="uri('mdss://t/data')"/>
                   <Variable Name="y"/>
                 </Variables>
                 <Sequence>
                   <InvokeActivity Activity="math.square" In.x="2" In.data="d"
                                   Out.y="y" Remotable="true"/>
                 </Sequence>
               </Workflow>"#,
        )
        .unwrap();
        let (part, _) = partitioner::partition(&wf).unwrap();
        engine.run(&part).unwrap();
        engine.run(&part).unwrap();
        // Both offloads moved the payload.
        assert_eq!(mgr.stats().data_syncs, 2);
        assert_eq!(mgr.stats().data_hits, 0);
    }

    #[test]
    fn parallel_remotable_steps_offload_concurrently() {
        // Fig 9b through the real migration manager: 4 parallel
        // remotable steps, each 200 ms reference -> sim time must be
        // ~one cloud step (50 ms) + WAN, not 4x.
        let services = Services::without_runtime(Platform::paper_testbed());
        let mut reg = ActivityRegistry::new();
        reg.register_fn("slow", |c, inputs| {
            c.charge_compute(Duration::from_millis(200));
            let x = need_num(inputs, "x")?;
            Ok([("y".to_string(), Value::Num(x))].into())
        });
        let reg = Arc::new(reg);
        let mgr = MigrationManager::in_proc(services.clone(), reg.clone(), DataPolicy::Mdss);
        let engine = Engine::new(reg, services).with_offload(mgr);
        let wf = xaml::parse(
            r#"<Workflow>
                 <Workflow.Variables>
                   <Variable Name="a"/><Variable Name="b"/>
                   <Variable Name="c"/><Variable Name="d"/>
                 </Workflow.Variables>
                 <Parallel>
                   <InvokeActivity Activity="slow" In.x="1" Out.y="a" Remotable="true"/>
                   <InvokeActivity Activity="slow" In.x="2" Out.y="b" Remotable="true"/>
                   <InvokeActivity Activity="slow" In.x="3" Out.y="c" Remotable="true"/>
                   <InvokeActivity Activity="slow" In.x="4" Out.y="d" Remotable="true"/>
                 </Parallel>
               </Workflow>"#,
        )
        .unwrap();
        let (part, _) = partitioner::partition(&wf).unwrap();
        let report = engine.run(&part).unwrap();
        assert_eq!(report.offload_count(), 4);
        // One offload ≈ 50 ms remote + ~20 ms WAN; sequential would be
        // ≥ 280 ms. Parallel must stay well under 2x one offload.
        assert!(
            report.sim_time < Duration::from_millis(140),
            "parallel offloads must overlap: {:?}",
            report.sim_time
        );
    }

    #[test]
    fn tcp_transport_end_to_end() {
        let services = Services::without_runtime(Platform::paper_testbed());
        let reg = registry();
        let worker = CloudWorker::new(services.clone(), reg.clone());
        let addr = serve_tcp(worker).unwrap();
        let transport = TcpTransport::connect(addr).unwrap();
        let mgr = MigrationManager::new(services.clone(), Box::new(transport), DataPolicy::Mdss);
        let engine = Engine::new(reg, services).with_offload(mgr);

        let wf = xaml::parse(
            r#"<Workflow>
                 <Variables><Variable Name="y"/></Variables>
                 <Sequence>
                   <InvokeActivity Activity="math.square" In.x="9" Out.y="y" Remotable="true"/>
                   <WriteLine Text="str(y)"/>
                 </Sequence>
               </Workflow>"#,
        )
        .unwrap();
        let (part, _) = partitioner::partition(&wf).unwrap();
        let report = engine.run(&part).unwrap();
        assert_eq!(report.lines, vec!["81"]);
    }
}
