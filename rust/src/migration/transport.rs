//! Transports between the local and cloud migration managers.
//!
//! The protocol bytes are real either way; only link *speed* is
//! simulated (by [`crate::cloud::SimNetwork`], charged by the caller).
//!
//! * [`InProcTransport`] — direct call into a cloud worker in the same
//!   process (the default for benches: deterministic, no sockets).
//! * [`TcpTransport`] — a real loopback TCP connection with
//!   length-prefixed frames, served by [`serve_tcp`]; exercises the
//!   full serialize → socket → deserialize path.

use std::io::{Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::{Arc, Mutex};

use anyhow::{bail, Context, Result};

/// A request/response byte transport.
pub trait Transport: Send + Sync {
    /// Send request bytes, receive response bytes.
    fn request(&self, bytes: &[u8]) -> Result<Vec<u8>>;
}

/// Serves requests on the remote side of a transport.
pub trait RequestHandler: Send + Sync {
    /// Handle one request, producing the response bytes.
    fn handle(&self, bytes: &[u8]) -> Vec<u8>;
}

/// Same-process transport: calls the handler directly.
pub struct InProcTransport {
    handler: Arc<dyn RequestHandler>,
}

impl InProcTransport {
    /// Wrap a handler.
    pub fn new(handler: Arc<dyn RequestHandler>) -> Self {
        Self { handler }
    }
}

impl Transport for InProcTransport {
    fn request(&self, bytes: &[u8]) -> Result<Vec<u8>> {
        Ok(self.handler.handle(bytes))
    }
}

// Frame format: u32 big-endian length + payload.
const MAX_FRAME: u32 = 256 * 1024 * 1024;

fn write_frame(stream: &mut TcpStream, bytes: &[u8]) -> Result<()> {
    let len = u32::try_from(bytes.len()).context("frame too large")?;
    if len > MAX_FRAME {
        bail!("frame of {len} bytes exceeds limit");
    }
    stream.write_all(&len.to_be_bytes())?;
    stream.write_all(bytes)?;
    stream.flush()?;
    Ok(())
}

fn read_frame(stream: &mut TcpStream) -> Result<Vec<u8>> {
    let mut len_buf = [0u8; 4];
    stream.read_exact(&mut len_buf).context("reading frame length")?;
    let len = u32::from_be_bytes(len_buf);
    if len > MAX_FRAME {
        bail!("peer announced oversized frame ({len} bytes)");
    }
    let mut payload = vec![0u8; len as usize];
    stream.read_exact(&mut payload).context("reading frame payload")?;
    Ok(payload)
}

/// TCP client transport (one persistent connection, serialized use).
pub struct TcpTransport {
    stream: Mutex<TcpStream>,
    /// Address of the connected worker.
    pub addr: SocketAddr,
}

impl TcpTransport {
    /// Connect to a worker served by [`serve_tcp`].
    pub fn connect(addr: SocketAddr) -> Result<Self> {
        let stream = TcpStream::connect(addr)
            .with_context(|| format!("connecting to cloud worker at {addr}"))?;
        stream.set_nodelay(true).ok();
        Ok(Self { stream: Mutex::new(stream), addr })
    }
}

impl Transport for TcpTransport {
    fn request(&self, bytes: &[u8]) -> Result<Vec<u8>> {
        let mut stream = self.stream.lock().unwrap();
        write_frame(&mut stream, bytes)?;
        read_frame(&mut stream)
    }
}

/// Start serving a handler over loopback TCP on an ephemeral port.
/// Returns the bound address; the accept loop runs on daemon threads
/// for the life of the process.
pub fn serve_tcp(handler: Arc<dyn RequestHandler>) -> Result<SocketAddr> {
    let listener = TcpListener::bind(("127.0.0.1", 0)).context("binding worker socket")?;
    let addr = listener.local_addr()?;
    std::thread::Builder::new()
        .name("emerald-cloud-accept".into())
        .spawn(move || {
            for conn in listener.incoming() {
                let Ok(mut stream) = conn else { continue };
                let handler = handler.clone();
                std::thread::Builder::new()
                    .name("emerald-cloud-conn".into())
                    .spawn(move || {
                        while let Ok(req) = read_frame(&mut stream) {
                            let resp = handler.handle(&req);
                            if write_frame(&mut stream, &resp).is_err() {
                                break;
                            }
                        }
                    })
                    .ok();
            }
        })
        .context("spawning worker accept thread")?;
    Ok(addr)
}

#[cfg(test)]
mod tests {
    use super::*;

    struct Echo;
    impl RequestHandler for Echo {
        fn handle(&self, bytes: &[u8]) -> Vec<u8> {
            let mut out = b"echo:".to_vec();
            out.extend_from_slice(bytes);
            out
        }
    }

    #[test]
    fn inproc_roundtrip() {
        let t = InProcTransport::new(Arc::new(Echo));
        assert_eq!(t.request(b"hi").unwrap(), b"echo:hi");
    }

    #[test]
    fn tcp_roundtrip() {
        let addr = serve_tcp(Arc::new(Echo)).unwrap();
        let t = TcpTransport::connect(addr).unwrap();
        assert_eq!(t.request(b"one").unwrap(), b"echo:one");
        // Connection reuse.
        assert_eq!(t.request(b"two").unwrap(), b"echo:two");
        // Large-ish frame.
        let big = vec![7u8; 1 << 20];
        let resp = t.request(&big).unwrap();
        assert_eq!(resp.len(), big.len() + 5);
    }

    #[test]
    fn tcp_multiple_clients() {
        let addr = serve_tcp(Arc::new(Echo)).unwrap();
        let a = TcpTransport::connect(addr).unwrap();
        let b = TcpTransport::connect(addr).unwrap();
        assert_eq!(a.request(b"a").unwrap(), b"echo:a");
        assert_eq!(b.request(b"b").unwrap(), b"echo:b");
    }
}
