//! Offload-request authentication — the paper's future-work §6
//! ("security concerns arise when code is offloaded to servers …
//! running foreign code on the server").
//!
//! Every offload request can carry a keyed SHA-256 tag over the task
//! code and inputs. The cloud worker verifies the tag before executing
//! anything: tampered task code (a modified step XML, injected inputs)
//! is rejected without execution. The key is shared out-of-band when
//! the worker is deployed (as the Emerald runtime itself is).

use sha2::{Digest, Sha256};

/// A shared signing key. `Debug` never prints key material.
#[derive(Clone)]
pub struct SigningKey {
    key: Vec<u8>,
}

impl std::fmt::Debug for SigningKey {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "SigningKey(<{} bytes redacted>)", self.key.len())
    }
}

impl SigningKey {
    /// Key from raw bytes.
    pub fn new(key: impl Into<Vec<u8>>) -> Self {
        Self { key: key.into() }
    }

    /// HMAC-style tag: SHA256(key || SHA256(key || message)), hex.
    /// (Length-extension safe for our fixed-format messages.)
    pub fn sign(&self, message: &[u8]) -> String {
        let inner: [u8; 32] = {
            let mut h = Sha256::new();
            h.update(&self.key);
            h.update(message);
            h.finalize().into()
        };
        let outer: [u8; 32] = {
            let mut h = Sha256::new();
            h.update(&self.key);
            h.update(inner);
            h.finalize().into()
        };
        hex(&outer)
    }

    /// Constant-time-ish verification (length + bytewise OR fold).
    pub fn verify(&self, message: &[u8], tag: &str) -> bool {
        let expect = self.sign(message);
        if expect.len() != tag.len() {
            return false;
        }
        expect
            .bytes()
            .zip(tag.bytes())
            .fold(0u8, |acc, (a, b)| acc | (a ^ b))
            == 0
    }
}

fn hex(bytes: &[u8]) -> String {
    bytes.iter().map(|b| format!("{b:02x}")).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sign_verify_roundtrip() {
        let key = SigningKey::new(b"emerald-secret".to_vec());
        let tag = key.sign(b"task code");
        assert_eq!(tag.len(), 64);
        assert!(key.verify(b"task code", &tag));
    }

    #[test]
    fn tamper_detected() {
        let key = SigningKey::new(b"emerald-secret".to_vec());
        let tag = key.sign(b"task code");
        assert!(!key.verify(b"task code!", &tag));
        assert!(!key.verify(b"task code", "deadbeef"));
    }

    #[test]
    fn wrong_key_rejected() {
        let k1 = SigningKey::new(b"alpha".to_vec());
        let k2 = SigningKey::new(b"beta".to_vec());
        let tag = k1.sign(b"msg");
        assert!(!k2.verify(b"msg", &tag));
    }

    #[test]
    fn deterministic() {
        let k = SigningKey::new(b"k".to_vec());
        assert_eq!(k.sign(b"m"), k.sign(b"m"));
        assert_ne!(k.sign(b"m"), k.sign(b"n"));
    }
}
