//! PJRT runtime: loads the AOT HLO-text artifacts produced by
//! `python/compile/aot.py` and executes them on the CPU PJRT client.
//!
//! This is the only module that touches XLA. Pattern (see
//! /opt/xla-example/load_hlo/): `PjRtClient::cpu()` →
//! `HloModuleProto::from_text_file` → `client.compile` → `execute`.
//! Compiled executables are cached per artifact name; the cache is the
//! difference between a ~100 ms compile and a ~µs lookup on the hot
//! path (measured by `benches/runtime_micro.rs`).

pub mod manifest;
pub mod tensor;

pub use manifest::{ArtifactSpec, Manifest, MeshSpec, TensorSig};
pub use tensor::HostTensor;

use std::collections::HashMap;
use std::sync::mpsc;
use std::sync::Mutex;
use std::time::{Duration, Instant};

use anyhow::{bail, Context, Result};

/// Statistics for one `execute` call.
#[derive(Debug, Clone, Copy)]
pub struct ExecStats {
    /// Wall time of the PJRT execution (compute only, excludes compile).
    pub compute: Duration,
    /// True when the executable came from the cache.
    pub cache_hit: bool,
}

enum Req {
    Execute {
        name: String,
        inputs: Vec<HostTensor>,
        resp: mpsc::Sender<Result<(Vec<HostTensor>, ExecStats)>>,
    },
    Warm {
        name: String,
        resp: mpsc::Sender<Result<()>>,
    },
    Platform {
        resp: mpsc::Sender<String>,
    },
}

/// The PJRT runtime handle.
///
/// The `xla` crate's client is not `Send`/`Sync` (it holds `Rc`s), so
/// all PJRT state — client, compiled-executable cache — lives on one
/// dedicated executor thread; this handle is a thread-safe facade over
/// an mpsc channel. On this single-CPU testbed serializing executions
/// costs nothing; simulated concurrency is modeled by the engine's
/// virtual-time composition, not by parallel PJRT calls.
pub struct Runtime {
    tx: Mutex<mpsc::Sender<Req>>,
    manifest: Manifest,
}

struct Executor {
    client: xla::PjRtClient,
    manifest: Manifest,
    cache: HashMap<String, xla::PjRtLoadedExecutable>,
}

impl Executor {
    fn executable(&mut self, name: &str) -> Result<(&xla::PjRtLoadedExecutable, bool)> {
        // (entry API would hold a borrow across the compile; keep it simple)
        let hit = self.cache.contains_key(name);
        if !hit {
            let spec = self.manifest.artifact(name)?;
            let proto = xla::HloModuleProto::from_text_file(
                spec.path
                    .to_str()
                    .with_context(|| format!("non-utf8 path {:?}", spec.path))?,
            )
            .with_context(|| format!("loading HLO text {}", spec.path.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self
                .client
                .compile(&comp)
                .with_context(|| format!("compiling artifact {name}"))?;
            self.cache.insert(name.to_string(), exe);
        }
        Ok((self.cache.get(name).unwrap(), hit))
    }

    fn execute(
        &mut self,
        name: &str,
        inputs: &[HostTensor],
    ) -> Result<(Vec<HostTensor>, ExecStats)> {
        let spec = self.manifest.artifact(name)?.clone();
        if inputs.len() != spec.inputs.len() {
            bail!(
                "artifact {name} expects {} inputs, got {}",
                spec.inputs.len(),
                inputs.len()
            );
        }
        for (i, (t, sig)) in inputs.iter().zip(&spec.inputs).enumerate() {
            if t.dims() != sig.dims.as_slice() {
                bail!(
                    "artifact {name} input {i}: expected shape {:?}, got {:?}",
                    sig.dims,
                    t.dims()
                );
            }
        }

        let (exe, cache_hit) = self.executable(name)?;
        let literals = inputs
            .iter()
            .map(HostTensor::to_literal)
            .collect::<Result<Vec<_>>>()?;

        let start = Instant::now();
        let result = exe.execute::<xla::Literal>(&literals)?;
        let mut tuple = result[0][0].to_literal_sync()?;
        let compute = start.elapsed();

        let elements = tuple.decompose_tuple()?;
        if elements.len() != spec.outputs.len() {
            bail!(
                "artifact {name} returned {} outputs, manifest says {}",
                elements.len(),
                spec.outputs.len()
            );
        }
        let outputs = elements
            .iter()
            .map(HostTensor::from_literal)
            .collect::<Result<Vec<_>>>()?;
        Ok((outputs, ExecStats { compute, cache_hit }))
    }

    fn serve(mut self, rx: mpsc::Receiver<Req>) {
        while let Ok(req) = rx.recv() {
            match req {
                Req::Execute { name, inputs, resp } => {
                    let _ = resp.send(self.execute(&name, &inputs));
                }
                Req::Warm { name, resp } => {
                    let _ = resp.send(self.executable(&name).map(|_| ()));
                }
                Req::Platform { resp } => {
                    let _ = resp.send(self.client.platform_name());
                }
            }
        }
    }
}

impl Runtime {
    /// Create a runtime over an artifact directory (must contain
    /// `manifest.json`; run `make artifacts` first). Spawns the
    /// executor thread.
    pub fn new(artifact_dir: impl AsRef<std::path::Path>) -> Result<Self> {
        let manifest = Manifest::load(artifact_dir)?;
        let (tx, rx) = mpsc::channel();
        let exec_manifest = manifest.clone();
        let (ready_tx, ready_rx) = mpsc::channel();
        std::thread::Builder::new()
            .name("emerald-pjrt".into())
            .spawn(move || {
                match xla::PjRtClient::cpu().context("creating PJRT CPU client") {
                    Ok(client) => {
                        let _ = ready_tx.send(Ok(()));
                        Executor { client, manifest: exec_manifest, cache: HashMap::new() }
                            .serve(rx);
                    }
                    Err(e) => {
                        let _ = ready_tx.send(Err(e));
                    }
                }
            })
            .context("spawning PJRT executor thread")?;
        ready_rx
            .recv()
            .context("PJRT executor thread died during startup")??;
        Ok(Self { tx: Mutex::new(tx), manifest })
    }

    fn send(&self, req: Req) {
        self.tx
            .lock()
            .unwrap()
            .send(req)
            .expect("PJRT executor thread is gone");
    }

    /// The manifest describing available artifacts and meshes.
    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// PJRT platform name (diagnostics).
    pub fn platform(&self) -> String {
        let (tx, rx) = mpsc::channel();
        self.send(Req::Platform { resp: tx });
        rx.recv().expect("PJRT executor thread is gone")
    }

    /// Pre-compile an artifact (warm the cache off the hot path).
    pub fn warm(&self, name: &str) -> Result<()> {
        let (tx, rx) = mpsc::channel();
        self.send(Req::Warm { name: name.to_string(), resp: tx });
        rx.recv().expect("PJRT executor thread is gone")
    }

    /// Execute an artifact with host tensors, returning host tensors.
    ///
    /// Inputs are validated against the manifest signature. The output
    /// tuple (artifacts are lowered with `return_tuple=True`) is
    /// decomposed into one tensor per element.
    pub fn execute(&self, name: &str, inputs: &[HostTensor]) -> Result<Vec<HostTensor>> {
        self.execute_with_stats(name, inputs).map(|(out, _)| out)
    }

    /// `execute` + timing/cache statistics.
    pub fn execute_with_stats(
        &self,
        name: &str,
        inputs: &[HostTensor],
    ) -> Result<(Vec<HostTensor>, ExecStats)> {
        let (tx, rx) = mpsc::channel();
        self.send(Req::Execute {
            name: name.to_string(),
            inputs: inputs.to_vec(),
            resp: tx,
        });
        rx.recv().expect("PJRT executor thread is gone")
    }
}

#[cfg(test)]
mod tests {
    // Runtime tests that need real artifacts live in rust/tests/
    // (integration), since unit tests should not depend on `make
    // artifacts` having run. Here we only check constructor failure.
    use super::*;

    #[test]
    fn missing_manifest_is_a_clean_error() {
        let err = match Runtime::new("/nonexistent/dir") {
            Err(e) => e,
            Ok(_) => panic!("constructor must fail"),
        };
        let msg = format!("{err:#}");
        assert!(msg.contains("manifest.json"), "unhelpful error: {msg}");
    }
}
