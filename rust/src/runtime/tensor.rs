//! Host-side tensors: the currency between the coordinator, MDSS and
//! the PJRT runtime. All Emerald artifacts operate on `f32` (the L2
//! model is single-precision), so `HostTensor` is an f32 nd-array with
//! row-major (C) layout.

use anyhow::{bail, Context, Result};

/// A dense, row-major f32 tensor on the host.
#[derive(Debug, Clone, PartialEq)]
pub struct HostTensor {
    dims: Vec<usize>,
    data: Vec<f32>,
}

impl HostTensor {
    /// Build from explicit dims + data (len must match).
    pub fn new(dims: Vec<usize>, data: Vec<f32>) -> Result<Self> {
        let n: usize = dims.iter().product();
        if n != data.len() {
            bail!(
                "tensor shape {:?} needs {} elements, got {}",
                dims,
                n,
                data.len()
            );
        }
        Ok(Self { dims, data })
    }

    /// All-zero tensor.
    pub fn zeros(dims: &[usize]) -> Self {
        Self { dims: dims.to_vec(), data: vec![0.0; dims.iter().product()] }
    }

    /// Constant-filled tensor.
    pub fn full(dims: &[usize], value: f32) -> Self {
        Self { dims: dims.to_vec(), data: vec![value; dims.iter().product()] }
    }

    /// Rank-0 scalar.
    pub fn scalar(value: f32) -> Self {
        Self { dims: vec![], data: vec![value] }
    }

    /// Shape accessor.
    pub fn dims(&self) -> &[usize] {
        &self.dims
    }

    /// Flat data accessor.
    pub fn data(&self) -> &[f32] {
        &self.data
    }

    /// Mutable flat data accessor.
    pub fn data_mut(&mut self) -> &mut [f32] {
        &mut self.data
    }

    /// Number of elements.
    pub fn len(&self) -> usize {
        self.data.len()
    }

    /// True when the tensor has zero elements.
    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    /// Size in bytes (the unit MDSS and the network simulator meter).
    pub fn size_bytes(&self) -> usize {
        self.data.len() * 4
    }

    /// Scalar extraction (rank-0 or single-element tensors).
    pub fn to_scalar(&self) -> Result<f32> {
        if self.data.len() != 1 {
            bail!("to_scalar on tensor with {} elements", self.data.len());
        }
        Ok(self.data[0])
    }

    /// 3-D indexed read (for tests / diagnostics).
    pub fn at3(&self, x: usize, y: usize, z: usize) -> f32 {
        let (ny, nz) = (self.dims[1], self.dims[2]);
        self.data[(x * ny + y) * nz + z]
    }

    /// Serialize to little-endian bytes (MDSS payload format).
    ///
    /// Hot path (§Perf): every tensor that crosses MDSS or the PJRT
    /// boundary goes through here. On little-endian targets (all our
    /// platforms) this is a single memcpy of the f32 buffer; the
    /// per-element encode is kept as the big-endian fallback.
    // Scoped exception to the crate-wide `deny(unsafe_code)`: the
    // little-endian fast path reinterprets the f32 buffer as bytes.
    #[allow(unsafe_code)]
    pub fn to_le_bytes(&self) -> Vec<u8> {
        #[cfg(target_endian = "little")]
        {
            let ptr = self.data.as_ptr() as *const u8;
            // SAFETY: f32 has no padding; len*4 bytes are initialized.
            let bytes = unsafe { std::slice::from_raw_parts(ptr, self.data.len() * 4) };
            bytes.to_vec()
        }
        #[cfg(not(target_endian = "little"))]
        {
            let mut out = Vec::with_capacity(self.data.len() * 4);
            for v in &self.data {
                out.extend_from_slice(&v.to_le_bytes());
            }
            out
        }
    }

    /// Deserialize from little-endian bytes with a known shape.
    // Scoped exception to the crate-wide `deny(unsafe_code)` (see
    // `to_le_bytes`).
    #[allow(unsafe_code)]
    pub fn from_le_bytes(dims: &[usize], bytes: &[u8]) -> Result<Self> {
        let n: usize = dims.iter().product();
        if bytes.len() != n * 4 {
            bail!("expected {} bytes for shape {:?}, got {}", n * 4, dims, bytes.len());
        }
        #[cfg(target_endian = "little")]
        let data = {
            let mut data = vec![0f32; n];
            // SAFETY: destination is n*4 initialized bytes; f32 from
            // arbitrary bit patterns is defined.
            unsafe {
                std::ptr::copy_nonoverlapping(
                    bytes.as_ptr(),
                    data.as_mut_ptr() as *mut u8,
                    n * 4,
                );
            }
            data
        };
        #[cfg(not(target_endian = "little"))]
        let data: Vec<f32> = bytes
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect();
        Ok(Self { dims: dims.to_vec(), data })
    }

    /// Load a raw little-endian f32 file (e.g. `artifacts/data/*.f32`).
    pub fn from_raw_file(dims: &[usize], path: &std::path::Path) -> Result<Self> {
        let bytes = std::fs::read(path)
            .with_context(|| format!("reading tensor file {}", path.display()))?;
        Self::from_le_bytes(dims, &bytes)
    }

    /// For a rank-2 tensor `[rows, cols]`: new tensor with the row order
    /// reversed (used to time-reverse the adjoint source).
    pub fn rows_reversed(&self) -> Result<Self> {
        if self.dims.len() != 2 {
            bail!("rows_reversed needs rank 2, got {:?}", self.dims);
        }
        let (rows, cols) = (self.dims[0], self.dims[1]);
        let mut data = Vec::with_capacity(self.data.len());
        for r in (0..rows).rev() {
            data.extend_from_slice(&self.data[r * cols..(r + 1) * cols]);
        }
        Ok(Self { dims: self.dims.clone(), data })
    }

    /// For a rank-2 tensor: copy rows `[start, start+len)`.
    pub fn row_chunk(&self, start: usize, len: usize) -> Result<Self> {
        if self.dims.len() != 2 {
            bail!("row_chunk needs rank 2, got {:?}", self.dims);
        }
        let (rows, cols) = (self.dims[0], self.dims[1]);
        if start + len > rows {
            bail!("row_chunk [{start}, {}) out of {rows} rows", start + len);
        }
        Ok(Self {
            dims: vec![len, cols],
            data: self.data[start * cols..(start + len) * cols].to_vec(),
        })
    }

    /// Concatenate rank-2 tensors along rows.
    pub fn concat_rows(parts: &[HostTensor]) -> Result<Self> {
        if parts.is_empty() {
            bail!("concat_rows of nothing");
        }
        let cols = parts[0].dims[1];
        let mut data = Vec::new();
        let mut rows = 0;
        for p in parts {
            if p.dims.len() != 2 || p.dims[1] != cols {
                bail!("concat_rows shape mismatch: {:?}", p.dims);
            }
            rows += p.dims[0];
            data.extend_from_slice(&p.data);
        }
        Ok(Self { dims: vec![rows, cols], data })
    }

    /// Max |x| over all elements.
    pub fn abs_max(&self) -> f32 {
        self.data.iter().fold(0.0f32, |m, v| m.max(v.abs()))
    }

    /// Convert to an XLA literal (copies into PJRT-owned memory).
    pub fn to_literal(&self) -> Result<xla::Literal> {
        let lit = xla::Literal::create_from_shape_and_untyped_data(
            xla::ElementType::F32,
            &self.dims,
            &self.to_le_bytes(),
        )?;
        Ok(lit)
    }

    /// Convert from an XLA literal (must be an f32 array).
    pub fn from_literal(lit: &xla::Literal) -> Result<Self> {
        let shape = lit.array_shape()?;
        let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
        let data = lit.to_vec::<f32>()?;
        Self::new(dims, data)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn new_validates_len() {
        assert!(HostTensor::new(vec![2, 3], vec![0.0; 6]).is_ok());
        assert!(HostTensor::new(vec![2, 3], vec![0.0; 5]).is_err());
    }

    #[test]
    fn le_bytes_roundtrip() {
        let t = HostTensor::new(vec![2, 2], vec![1.5, -2.0, 0.0, 3.25]).unwrap();
        let back = HostTensor::from_le_bytes(&[2, 2], &t.to_le_bytes()).unwrap();
        assert_eq!(t, back);
    }

    #[test]
    fn at3_row_major() {
        let mut t = HostTensor::zeros(&[2, 3, 4]);
        t.data_mut()[(1 * 3 + 2) * 4 + 3] = 7.0;
        assert_eq!(t.at3(1, 2, 3), 7.0);
    }

    #[test]
    fn rows_reversed_involution() {
        let t = HostTensor::new(vec![3, 2], vec![1., 2., 3., 4., 5., 6.]).unwrap();
        let r = t.rows_reversed().unwrap();
        assert_eq!(r.data(), &[5., 6., 3., 4., 1., 2.]);
        assert_eq!(r.rows_reversed().unwrap(), t);
    }

    #[test]
    fn row_chunk_and_concat_invert() {
        let t = HostTensor::new(vec![4, 2], (0..8).map(|i| i as f32).collect()).unwrap();
        let a = t.row_chunk(0, 2).unwrap();
        let b = t.row_chunk(2, 2).unwrap();
        assert_eq!(HostTensor::concat_rows(&[a, b]).unwrap(), t);
    }

    #[test]
    fn row_chunk_bounds() {
        let t = HostTensor::zeros(&[4, 2]);
        assert!(t.row_chunk(3, 2).is_err());
    }

    #[test]
    fn scalar_roundtrip() {
        let s = HostTensor::scalar(2.5);
        assert_eq!(s.dims(), &[] as &[usize]);
        assert_eq!(s.to_scalar().unwrap(), 2.5);
        assert!(HostTensor::zeros(&[2]).to_scalar().is_err());
    }

    #[test]
    fn abs_max() {
        let t = HostTensor::new(vec![3], vec![-5.0, 2.0, 4.0]).unwrap();
        assert_eq!(t.abs_max(), 5.0);
    }
}
