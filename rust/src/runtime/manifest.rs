//! The artifact manifest: machine-readable index written by
//! `python/compile/aot.py` describing every AOT artifact (file name +
//! input/output signatures) and every mesh configuration.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::jsonmini::{self, Value};

/// Signature of one tensor argument/result: shape only (all artifacts
/// are f32; the dtype field in the manifest is validated).
#[derive(Debug, Clone, PartialEq)]
pub struct TensorSig {
    /// Tensor shape (row-major dimensions).
    pub dims: Vec<usize>,
}

impl TensorSig {
    fn from_json(v: &Value) -> Result<Self> {
        let pair = v.as_arr()?;
        if pair.len() != 2 {
            bail!("signature entry must be [dtype, shape]");
        }
        let dtype = pair[0].as_str()?;
        if dtype != "f32" {
            bail!("unsupported artifact dtype {dtype}");
        }
        let dims = pair[1]
            .as_arr()?
            .iter()
            .map(|d| Ok(d.as_usize()?))
            .collect::<Result<Vec<_>>>()?;
        Ok(Self { dims })
    }

    /// Element count.
    pub fn len(&self) -> usize {
        self.dims.iter().product()
    }

    /// Size in bytes.
    pub fn size_bytes(&self) -> usize {
        self.len() * 4
    }
}

/// One AOT artifact (an HLO-text file plus its signature).
#[derive(Debug, Clone)]
pub struct ArtifactSpec {
    /// Artifact name (manifest key).
    pub name: String,
    /// HLO-text file path, relative to the manifest directory.
    pub path: PathBuf,
    /// Input tensor signatures, in call order.
    pub inputs: Vec<TensorSig>,
    /// Output tensor signatures, in result order.
    pub outputs: Vec<TensorSig>,
}

/// One mesh configuration (an AT workload; paper §4 inputs).
#[derive(Debug, Clone)]
pub struct MeshSpec {
    /// Mesh name (manifest key).
    pub name: String,
    /// Grid dimensions (nx, ny, nz).
    pub shape: [usize; 3],
    /// Total time steps per simulation.
    pub nt: usize,
    /// Time steps per chunked artifact call.
    pub chunk: usize,
    /// Time-step size in seconds.
    pub dt: f32,
    /// Source wavelet peak frequency (Hz).
    pub f0: f32,
    /// Source grid position.
    pub source: [usize; 3],
    /// Receiver grid positions.
    pub receivers: Vec<[usize; 3]>,
    /// Reference wave speed (initial model value).
    pub c_ref: f32,
    /// Lower clamp on inverted wave speeds.
    pub c_min: f32,
    /// Upper clamp on inverted wave speeds.
    pub c_max: f32,
    /// File holding the ground-truth model (relative to the manifest).
    pub true_model_file: PathBuf,
}

impl MeshSpec {
    /// Number of chunked artifact calls per simulation.
    pub fn n_chunks(&self) -> usize {
        self.nt / self.chunk
    }

    /// Number of receivers.
    pub fn n_rec(&self) -> usize {
        self.receivers.len()
    }

    /// Field element count.
    pub fn cells(&self) -> usize {
        self.shape.iter().product()
    }

    /// Field size in bytes (one wavefield / model tensor).
    pub fn field_bytes(&self) -> usize {
        self.cells() * 4
    }
}

/// Parsed manifest.
#[derive(Debug, Clone)]
pub struct Manifest {
    /// Directory the manifest was loaded from (resolves artifact paths).
    pub dir: PathBuf,
    /// Artifacts by name.
    pub artifacts: BTreeMap<String, ArtifactSpec>,
    /// Meshes by name.
    pub meshes: BTreeMap<String, MeshSpec>,
}

fn triple(v: &Value) -> Result<[usize; 3]> {
    let a = v.as_arr()?;
    if a.len() != 3 {
        bail!("expected a 3-element array");
    }
    Ok([a[0].as_usize()?, a[1].as_usize()?, a[2].as_usize()?])
}

impl Manifest {
    /// Load `manifest.json` from an artifact directory.
    pub fn load(dir: impl AsRef<Path>) -> Result<Self> {
        let dir = dir.as_ref().to_path_buf();
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {} (run `make artifacts`)", path.display()))?;
        Self::parse(&text, dir)
    }

    /// Parse manifest JSON text (exposed for tests).
    pub fn parse(text: &str, dir: PathBuf) -> Result<Self> {
        let root = jsonmini::parse(text).context("parsing manifest.json")?;
        let version = root.get("version")?.as_usize()?;
        if version != 1 {
            bail!("unsupported manifest version {version}");
        }

        let mut artifacts = BTreeMap::new();
        for (name, spec) in root.get("artifacts")?.as_obj()? {
            let inputs = spec
                .get("inputs")?
                .as_arr()?
                .iter()
                .map(TensorSig::from_json)
                .collect::<Result<Vec<_>>>()?;
            let outputs = spec
                .get("outputs")?
                .as_arr()?
                .iter()
                .map(TensorSig::from_json)
                .collect::<Result<Vec<_>>>()?;
            artifacts.insert(
                name.clone(),
                ArtifactSpec {
                    name: name.clone(),
                    path: dir.join(spec.get("file")?.as_str()?),
                    inputs,
                    outputs,
                },
            );
        }

        let mut meshes = BTreeMap::new();
        for (name, m) in root.get("meshes")?.as_obj()? {
            let receivers = m
                .get("receivers")?
                .as_arr()?
                .iter()
                .map(triple)
                .collect::<Result<Vec<_>>>()?;
            meshes.insert(
                name.clone(),
                MeshSpec {
                    name: name.clone(),
                    shape: triple(m.get("shape")?)?,
                    nt: m.get("nt")?.as_usize()?,
                    chunk: m.get("chunk")?.as_usize()?,
                    dt: m.get("dt")?.as_f64()? as f32,
                    f0: m.get("f0")?.as_f64()? as f32,
                    source: triple(m.get("source")?)?,
                    receivers,
                    c_ref: m.get("c_ref")?.as_f64()? as f32,
                    c_min: m.get("c_min")?.as_f64()? as f32,
                    c_max: m.get("c_max")?.as_f64()? as f32,
                    true_model_file: dir.join(m.get("true_model_file")?.as_str()?),
                },
            );
        }

        Ok(Self { dir, artifacts, meshes })
    }

    /// Lookup an artifact spec by name.
    pub fn artifact(&self, name: &str) -> Result<&ArtifactSpec> {
        self.artifacts
            .get(name)
            .with_context(|| format!("artifact {name} not in manifest"))
    }

    /// Lookup a mesh spec by name.
    pub fn mesh(&self, name: &str) -> Result<&MeshSpec> {
        self.meshes
            .get(name)
            .with_context(|| format!("mesh {name} not in manifest"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
        "version": 1,
        "artifacts": {
            "vecadd": {"file": "vecadd.hlo.txt",
                       "inputs": [["f32", [8]], ["f32", [8]]],
                       "outputs": [["f32", [8]]]}
        },
        "meshes": {
            "demo": {"shape": [24,16,16], "nt": 40, "chunk": 8,
                     "dt": 0.15, "f0": 0.25, "source": [12,8,8],
                     "receivers": [[5,8,3],[10,8,3]],
                     "c_ref": 2.0, "c_min": 1.2, "c_max": 3.5,
                     "true_model_file": "data/demo_true_c.f32"}
        }
    }"#;

    #[test]
    fn parses_sample() {
        let m = Manifest::parse(SAMPLE, PathBuf::from("/x")).unwrap();
        let a = m.artifact("vecadd").unwrap();
        assert_eq!(a.inputs.len(), 2);
        assert_eq!(a.inputs[0].dims, vec![8]);
        assert_eq!(a.path, PathBuf::from("/x/vecadd.hlo.txt"));
        let mesh = m.mesh("demo").unwrap();
        assert_eq!(mesh.shape, [24, 16, 16]);
        assert_eq!(mesh.n_chunks(), 5);
        assert_eq!(mesh.n_rec(), 2);
        assert_eq!(mesh.field_bytes(), 24 * 16 * 16 * 4);
    }

    #[test]
    fn unknown_lookups_fail() {
        let m = Manifest::parse(SAMPLE, PathBuf::from("/x")).unwrap();
        assert!(m.artifact("nope").is_err());
        assert!(m.mesh("nope").is_err());
    }

    #[test]
    fn rejects_wrong_version() {
        let bad = SAMPLE.replace("\"version\": 1", "\"version\": 2");
        assert!(Manifest::parse(&bad, PathBuf::from("/x")).is_err());
    }

    #[test]
    fn rejects_non_f32() {
        let bad = SAMPLE.replace("[\"f32\", [8]], [\"f32\", [8]]", "[\"f64\", [8]], [\"f32\", [8]]");
        assert!(Manifest::parse(&bad, PathBuf::from("/x")).is_err());
    }
}
