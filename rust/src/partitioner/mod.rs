//! The Emerald partitioner (paper §3.1, Figures 5–6).
//!
//! Input: an *annotated workflow* (steps marked `Remotable="true"`).
//! Output: a *modified workflow with migration points* — a temporary
//! [`StepKind::MigrationPoint`] step inserted immediately **before**
//! each remotable step. At runtime the temporary step suspends the
//! workflow, notifies the migration manager to offload the step, and
//! resumes execution after re-integration (Figure 6).
//!
//! Partitioning validates the three legal-partition properties first
//! ([`crate::workflow::validate`]); any annotated WF workflow that
//! follows the rules can be partitioned.
//!
//! ## Offload batching ([`PartitionOptions::batch`])
//!
//! A run of **consecutive remotable siblings in a `Sequence`** pays one
//! synchronous WAN round trip per step under plain partitioning. With
//! batching enabled, the partitioner fuses each maximal run of ≥ 2
//! consecutive remotable steps into a single migration point whose
//! target is a synthetic `Sequence` of the run members, amortizing the
//! suspend → uplink → execute → downlink cycle across the whole run.
//! Intermediate values (written by one member, read by the next) stay
//! on the cloud — the flow-aware [`crate::workflow::analysis`] keeps
//! them out of the request's input set.
//!
//! Fusion is legal under the paper's properties because it only groups
//! steps that individually passed validation: no member touches local
//! hardware (P1), every member's I/O variables are declared at the
//! run's own scope level, which is also the fused step's level (P2),
//! and no member contains nested remotable steps (P3) — so the fused
//! sequence offloads exactly once, with one suspend/resume pair.
//! Fusion never crosses a non-remotable step, a scope boundary, or
//! `Parallel`/`If`/`While` branch boundaries.
//!
//! ## Dataflow-aware batching ([`PartitionOptions::dataflow`])
//!
//! Whole-run fusion is the right call for the sequential engine —
//! every round trip it removes is pure WAN savings. Under the
//! dataflow engine it can *cost* time: fusing two **independent**
//! remotable siblings into one offload unit serializes work the
//! dependence DAG would have offloaded to two VMs concurrently. With
//! `dataflow` set alongside `batch`, the partitioner therefore fuses
//! only **dependent** sub-runs ([`crate::workflow::dag::dependent_runs`]):
//! walking each maximal run of consecutive remotable siblings in
//! program order, a step joins the open sub-run only when it conflicts
//! (write→read / write→write / read→write) with an earlier member of
//! that sub-run. A dependent chain has no parallelism to lose — its
//! members could never overlap — so fusing it is all savings; steps
//! independent of the open sub-run stay separate offload units the
//! DAG can run concurrently. Steps are never reordered. When a
//! member's expressions defeat the analysis, the run falls back to
//! whole-run fusion, which is always legal (and the dataflow engine
//! falls back to the sequential walk on the same workflows, so no
//! parallelism is lost that the engine could have exploited).
//!
//! **Loop bodies fuse whole runs.** Inside a `While` or `ForEach`
//! body the trade flips: the body re-executes every iteration, so a
//! split run multiplies its extra WAN round trips by the iteration
//! count, while the overlap the split was protecting is confined to a
//! single iteration — and the whole-workflow IR executor walks each
//! iteration's (or each scattered element's) body sequentially, where
//! split points are pure round-trip loss with no overlap at all.
//! Runs inside loop bodies therefore always take whole-run fusion,
//! exactly the fallback shape, even when the body is analyzable;
//! `--batch --dataflow` never silently degrades to the unbatched
//! point-per-step shape inside a loop.

use anyhow::Result;

use crate::workflow::{dag, validate, Step, StepKind, Workflow};

/// Partitioning statistics.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct PartitionReport {
    /// Number of migration points inserted.
    pub migration_points: usize,
    /// Steps in the workflow before partitioning.
    pub steps_before: usize,
    /// Steps in the workflow after partitioning (points included).
    pub steps_after: usize,
    /// Number of fused multi-step batches (0 without batching).
    pub batches: usize,
    /// Total remotable steps carried inside fused batches.
    pub batched_steps: usize,
    /// Variables classified **cloud-to-cloud** on the partitioned
    /// output: written by one offload unit and read only by other
    /// offload units ([`crate::workflow::ir::Ir::resident_vars`]).
    /// These are the hazard edges the migration manager turns into
    /// `mdss://` reference-passing under `[migration] resident`;
    /// everything else (local↔cloud edges) ships by value. Zero when
    /// the workflow defeats IR compilation.
    pub resident_vars: usize,
}

/// Partitioner knobs.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct PartitionOptions {
    /// Fuse runs of consecutive remotable sequence siblings into one
    /// migration point (see module docs). Off by default: one point
    /// per remotable step, the paper's Figure-5 shape.
    pub batch: bool,
    /// The workflow will run under the engine's dataflow mode: fuse
    /// only *dependent* sub-runs, keeping independent remotable
    /// siblings separate offload units the dependence DAG can run
    /// concurrently (see "Dataflow-aware batching" in the module
    /// docs). No effect unless `batch` is also set.
    pub dataflow: bool,
}

#[derive(Default)]
struct RewriteStats {
    inserted: usize,
    batches: usize,
    batched_steps: usize,
}

/// Validate and partition a workflow with default options. The input
/// is unchanged; the returned workflow contains the inserted migration
/// points.
pub fn partition(wf: &Workflow) -> Result<(Workflow, PartitionReport)> {
    partition_with(wf, PartitionOptions::default())
}

/// Validate and partition with explicit options.
pub fn partition_with(
    wf: &Workflow,
    opts: PartitionOptions,
) -> Result<(Workflow, PartitionReport)> {
    validate::validate(wf)?;
    let steps_before = wf.size();

    let mut out = wf.clone();
    let mut stats = RewriteStats::default();
    rewrite(&mut out.root, opts, &mut stats, false);
    out.renumber();

    // Classify the partitioned output's hazard edges: variables that
    // flow offload -> offload only are candidates for cloud-resident
    // reference-passing. One classifier serves the partitioner, the
    // manager and the engine (`workflow::ir`), so the report can never
    // disagree with what execution does.
    let resident_vars = crate::workflow::ir::Ir::compile(&out.root)
        .map(|ir| ir.resident_vars().len())
        .unwrap_or(0);

    let report = PartitionReport {
        migration_points: stats.inserted,
        steps_before,
        steps_after: out.size(),
        batches: stats.batches,
        batched_steps: stats.batched_steps,
        resident_vars,
    };
    Ok((out, report))
}

/// Insert migration points in-place.
///
/// * Remotable children of a `Sequence` get a `MigrationPoint` sibling
///   inserted before them; with batching, maximal runs of consecutive
///   remotable children share one point behind a fused `Sequence`.
/// * Remotable children of other containers (`Parallel` branches, `If`
///   branches, `While`/`ForEach` bodies) are wrapped in a small
///   `Sequence` [MigrationPoint, step] so the engine's sequence
///   scanner finds them; each parallel branch therefore offloads
///   independently (Figure 9b), and each scattered `ForEach` element
///   takes its own cloud lease.
///
/// `in_loop` tracks whether we are inside a `While`/`ForEach` body:
/// runs there always take whole-run fusion (see module docs).
fn rewrite(step: &mut Step, opts: PartitionOptions, stats: &mut RewriteStats, in_loop: bool) {
    match &mut step.kind {
        StepKind::Sequence(children) => {
            let old = std::mem::take(children);
            let mut rebuilt = Vec::with_capacity(old.len() + 2);
            let mut run: Vec<Step> = Vec::new();
            for mut c in old {
                if c.remotable {
                    // P3 guarantees nothing remotable inside: no recursion.
                    run.push(c);
                    if !opts.batch {
                        flush_run(&mut run, &mut rebuilt, opts, stats, in_loop);
                    }
                } else {
                    flush_run(&mut run, &mut rebuilt, opts, stats, in_loop);
                    rewrite(&mut c, opts, stats, in_loop);
                    rebuilt.push(c);
                }
            }
            flush_run(&mut run, &mut rebuilt, opts, stats, in_loop);
            *children = rebuilt;
        }
        StepKind::Parallel(children) => {
            for c in children.iter_mut() {
                if c.remotable {
                    wrap_in_sequence(c);
                    stats.inserted += 1;
                } else {
                    rewrite(c, opts, stats, in_loop);
                }
            }
        }
        StepKind::If { then_branch, else_branch, .. } => {
            for b in [Some(then_branch), else_branch.as_mut()].into_iter().flatten() {
                if b.remotable {
                    wrap_in_sequence(b);
                    stats.inserted += 1;
                } else {
                    rewrite(b, opts, stats, in_loop);
                }
            }
        }
        StepKind::While { body, .. } | StepKind::ForEach { body, .. } => {
            if body.remotable {
                wrap_in_sequence(body);
                stats.inserted += 1;
            } else {
                rewrite(body, opts, stats, true);
            }
        }
        _ => {}
    }
}

/// Emit the pending run of remotable steps. Plain batching fuses the
/// whole run; with `dataflow` also set, the run is first split into
/// maximal dependent sub-runs ([`dag::dependent_runs`]) and each
/// sub-run is emitted on its own — independent siblings keep separate
/// migration points for the dataflow engine to overlap. An
/// unanalyzable run (an expression the flow analysis cannot parse)
/// falls back to whole-run fusion, which is legal regardless of
/// analysis — and so does any run inside a `While`/`ForEach` body
/// (`in_loop`), where the split would multiply round trips per
/// iteration for overlap confined to a single one (module docs,
/// "Loop bodies fuse whole runs").
fn flush_run(
    run: &mut Vec<Step>,
    out: &mut Vec<Step>,
    opts: PartitionOptions,
    stats: &mut RewriteStats,
    in_loop: bool,
) {
    if opts.dataflow && !in_loop && run.len() >= 2 {
        let members = std::mem::take(run);
        match dag::dependent_runs(&members) {
            Ok(spans) => {
                let mut iter = members.into_iter();
                for (_, len) in spans {
                    let mut chunk: Vec<Step> = iter.by_ref().take(len).collect();
                    emit_chunk(&mut chunk, out, stats);
                }
            }
            Err(_) => {
                let mut chunk = members;
                emit_chunk(&mut chunk, out, stats);
            }
        }
        return;
    }
    emit_chunk(run, out, stats);
}

/// Emit one chunk of remotable steps: a single step gets its own
/// migration point; two or more fuse into one point behind a synthetic
/// sequence.
fn emit_chunk(run: &mut Vec<Step>, out: &mut Vec<Step>, stats: &mut RewriteStats) {
    match run.len() {
        0 => {}
        1 => {
            out.push(migration_point());
            out.push(run.pop().expect("length checked"));
            stats.inserted += 1;
        }
        n => {
            let members = std::mem::take(run);
            let label = members
                .iter()
                .map(|s| s.display_name.as_str())
                .collect::<Vec<_>>()
                .join("+");
            out.push(migration_point());
            out.push(Step::new(format!("batch({label})"), StepKind::Sequence(members)));
            stats.inserted += 1;
            stats.batches += 1;
            stats.batched_steps += n;
        }
    }
}

fn migration_point() -> Step {
    Step::new("migration-point", StepKind::MigrationPoint)
}

fn wrap_in_sequence(step: &mut Step) {
    let inner = std::mem::replace(step, Step::new("tmp", StepKind::Nop));
    *step = Step::new(
        format!("offload({})", inner.display_name),
        StepKind::Sequence(vec![migration_point(), inner]),
    );
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quickprop::{forall, Gen};

    fn assign(to: &str, value: &str) -> Step {
        Step::new(to, StepKind::Assign { to: to.into(), value: value.into() })
    }

    fn wf(steps: Vec<Step>) -> Workflow {
        Workflow::new("t", Step::new("main", StepKind::Sequence(steps)))
            .var("a", Some("1"))
            .var("b", Some("2"))
            .var("c", Some("3"))
    }

    fn batched() -> PartitionOptions {
        PartitionOptions { batch: true, ..Default::default() }
    }

    fn dataflow_batched() -> PartitionOptions {
        PartitionOptions { batch: true, dataflow: true }
    }

    #[test]
    fn inserts_point_before_remotable() {
        let w = wf(vec![assign("a", "1"), assign("b", "a + 1").remotable(), assign("c", "b")]);
        let (out, report) = partition(&w).unwrap();
        assert_eq!(report.migration_points, 1);
        assert_eq!(report.steps_after, report.steps_before + 1);
        assert_eq!(report.batches, 0);
        let kids = out.root.children();
        assert_eq!(kids[1].kind_name(), "MigrationPoint");
        assert_eq!(kids[2].display_name, "b");
    }

    #[test]
    fn wraps_parallel_branches() {
        let w = Workflow::new(
            "p",
            Step::new(
                "main",
                StepKind::Parallel(vec![
                    assign("a", "1").remotable(),
                    assign("b", "2"),
                ]),
            ),
        )
        .var("a", None)
        .var("b", None);
        let (out, report) = partition(&w).unwrap();
        assert_eq!(report.migration_points, 1);
        let branch = out.root.children()[0];
        assert_eq!(branch.kind_name(), "Sequence");
        assert_eq!(branch.children()[0].kind_name(), "MigrationPoint");
        // Non-remotable branch untouched.
        assert_eq!(out.root.children()[1].kind_name(), "Assign");
    }

    #[test]
    fn validation_failures_propagate() {
        let w = wf(vec![assign("a", "1").remotable().local_hardware()]);
        assert!(partition(&w).is_err());
    }

    #[test]
    fn no_remotable_steps_is_identity() {
        let w = wf(vec![assign("a", "1"), assign("b", "2")]);
        let (out, report) = partition(&w).unwrap();
        assert_eq!(report.migration_points, 0);
        assert_eq!(out, w);
    }

    #[test]
    fn idempotent_guard_rejects_repartition() {
        let w = wf(vec![assign("a", "1").remotable()]);
        let (out, _) = partition(&w).unwrap();
        // Partitioning an already-partitioned workflow is an error
        // (validate rejects existing MigrationPoints).
        assert!(partition(&out).is_err());
    }

    #[test]
    fn batching_fuses_consecutive_remotable_runs() {
        let w = wf(vec![
            assign("a", "1"),
            assign("b", "a + 1").remotable(),
            assign("c", "b + 1").remotable(),
            assign("a", "c + 1").remotable(),
        ]);
        let (out, report) = partition_with(&w, batched()).unwrap();
        assert_eq!(report.migration_points, 1);
        assert_eq!(report.batches, 1);
        assert_eq!(report.batched_steps, 3);
        let kids = out.root.children();
        assert_eq!(kids[1].kind_name(), "MigrationPoint");
        let fused = kids[2];
        assert_eq!(fused.kind_name(), "Sequence");
        assert_eq!(fused.children().len(), 3);
        assert!(fused.display_name.starts_with("batch("));
    }

    #[test]
    fn report_classifies_cloud_to_cloud_edges() {
        // a flows offload -> offload only; b is read by a local step.
        let w = wf(vec![
            assign("a", "1").remotable(),
            assign("b", "a + 1").remotable(),
            assign("c", "b"),
        ]);
        let (_, report) = partition(&w).unwrap();
        assert_eq!(report.resident_vars, 1, "only 'a' stays cloud-to-cloud");
        // All-local workflows classify zero.
        let (_, local) = partition(&wf(vec![assign("a", "1"), assign("b", "a")])).unwrap();
        assert_eq!(local.resident_vars, 0);
    }

    #[test]
    fn batching_does_not_cross_local_steps() {
        let w = wf(vec![
            assign("a", "1").remotable(),
            assign("b", "a"),
            assign("c", "b").remotable(),
        ]);
        let (_, report) = partition_with(&w, batched()).unwrap();
        assert_eq!(report.migration_points, 2);
        assert_eq!(report.batches, 0, "runs broken by a local step don't fuse");
    }

    #[test]
    fn batching_off_by_default_matches_seed_shape() {
        let w = wf(vec![
            assign("a", "1").remotable(),
            assign("b", "a").remotable(),
        ]);
        let (_, plain) = partition(&w).unwrap();
        assert_eq!(plain.migration_points, 2);
        let (_, fused) = partition_with(&w, batched()).unwrap();
        assert_eq!(fused.migration_points, 1);
        assert_eq!(fused.batched_steps, 2);
    }

    #[test]
    fn dataflow_batching_fuses_only_dependent_runs() {
        // a=1 ; b=a (dependent) ; c=9 (independent of both): plain
        // batching fuses all three; dataflow-aware batching fuses the
        // a→b chain and leaves c its own offload unit to overlap.
        let w = wf(vec![
            assign("a", "1").remotable(),
            assign("b", "a + 1").remotable(),
            assign("c", "9").remotable(),
        ]);
        let (_, plain) = partition_with(&w, batched()).unwrap();
        assert_eq!((plain.migration_points, plain.batched_steps), (1, 3));
        let (out, df) = partition_with(&w, dataflow_batched()).unwrap();
        assert_eq!(df.migration_points, 2, "independent step keeps its own point");
        assert_eq!((df.batches, df.batched_steps), (1, 2), "only the chain fuses");
        let kids = out.root.children();
        assert_eq!(kids[0].kind_name(), "MigrationPoint");
        assert!(kids[1].display_name.starts_with("batch("), "{}", kids[1].display_name);
        assert_eq!(kids[2].kind_name(), "MigrationPoint");
        assert_eq!(kids[3].display_name, "c");
    }

    #[test]
    fn dataflow_batching_without_dependence_is_point_per_step() {
        // A fully independent run degenerates to unbatched shape.
        let w = wf(vec![
            assign("a", "1").remotable(),
            assign("b", "2").remotable(),
            assign("c", "3").remotable(),
        ]);
        let (out, report) = partition_with(&w, dataflow_batched()).unwrap();
        assert_eq!(report.migration_points, 3);
        assert_eq!(report.batches, 0);
        let (unbatched_out, unbatched) = partition(&w).unwrap();
        assert_eq!(unbatched.migration_points, 3);
        assert_eq!(out, unbatched_out, "no dependence -> identical to plain partitioning");
    }

    #[test]
    fn dataflow_batching_fuses_fully_dependent_chains_whole() {
        let w = wf(vec![
            assign("a", "1").remotable(),
            assign("b", "a").remotable(),
            assign("c", "b").remotable(),
        ]);
        let (_, report) = partition_with(&w, dataflow_batched()).unwrap();
        assert_eq!(report.migration_points, 1);
        assert_eq!(report.batched_steps, 3, "a chain has no parallelism to protect");
    }

    #[test]
    fn dataflow_flag_alone_does_not_batch() {
        let w = wf(vec![
            assign("a", "1").remotable(),
            assign("b", "a").remotable(),
        ]);
        let (_, report) =
            partition_with(&w, PartitionOptions { batch: false, dataflow: true }).unwrap();
        assert_eq!(report.migration_points, 2);
        assert_eq!(report.batches, 0, "dataflow only modulates batching");
    }

    #[test]
    fn foreach_bodies_get_wrapped() {
        let body = assign("acc", "item * 2").remotable();
        let w = Workflow::new(
            "fe",
            Step::new(
                "loop",
                StepKind::ForEach {
                    var: "item".into(),
                    collection: "range(3)".into(),
                    yield_var: Some("acc".into()),
                    out: Some("results".into()),
                    body: Box::new(body),
                },
            ),
        )
        .var("results", None);
        let (out, report) = partition(&w).unwrap();
        assert_eq!(report.migration_points, 1);
        let wrapped = out.root.children()[0];
        assert_eq!(wrapped.kind_name(), "Sequence");
        assert!(wrapped.display_name.starts_with("offload("));
        assert_eq!(wrapped.children()[0].kind_name(), "MigrationPoint");
    }

    #[test]
    fn loop_bodies_fuse_whole_runs_under_dataflow_batching() {
        // The same independent remotable run splits point-per-step at
        // top level (dataflow-aware batching) but fuses whole inside a
        // While body: per-iteration round trips dominate there, and
        // the IR executor walks loop bodies sequentially anyway.
        let body = Step::new(
            "body",
            StepKind::Sequence(vec![
                assign("a", "1").remotable(),
                assign("b", "2").remotable(),
                assign("i", "i + 1"),
            ]),
        );
        let w = Workflow::new(
            "loop",
            Step::new(
                "w",
                StepKind::While {
                    condition: "i < 2".into(),
                    body: Box::new(body),
                    max_iters: 10,
                },
            ),
        )
        .var("i", Some("0"))
        .var("a", None)
        .var("b", None);
        let (_, report) = partition_with(&w, dataflow_batched()).unwrap();
        assert_eq!(report.migration_points, 1, "whole-run fusion inside the loop body");
        assert_eq!((report.batches, report.batched_steps), (1, 2));
    }

    #[test]
    fn property_one_point_per_remotable_step() {
        // Random workflows: #migration points == #remotable steps, and
        // the step order is preserved.
        forall(60, |g: &mut Gen| {
            let n = g.usize_in(1..=12);
            let mut steps = Vec::new();
            let mut expect_remote = 0;
            for i in 0..n {
                let mut s = assign(["a", "b", "c"][i % 3], &format!("{i}"));
                if g.bool() {
                    s = s.remotable();
                    expect_remote += 1;
                }
                steps.push(s);
            }
            let w = wf(steps);
            let (out, report) = partition(&w).unwrap();
            assert_eq!(report.migration_points, expect_remote);
            // Order of Assign display names preserved.
            let names = |w: &Workflow| {
                let mut v = Vec::new();
                w.root.walk(&mut |s| {
                    if s.kind_name() == "Assign" {
                        v.push(s.display_name.clone());
                    }
                });
                v
            };
            assert_eq!(names(&w), names(&out));
        });
    }

    #[test]
    fn property_batched_points_match_run_count() {
        // Batched partitioning: #migration points == #maximal runs of
        // consecutive remotable steps; assign order is preserved.
        forall(60, |g: &mut Gen| {
            let n = g.usize_in(1..=14);
            let mut steps = Vec::new();
            let mut runs = 0usize;
            let mut prev_remote = false;
            for i in 0..n {
                let mut s = assign(["a", "b", "c"][i % 3], &format!("{i}"));
                let remote = g.bool();
                if remote {
                    s = s.remotable();
                    if !prev_remote {
                        runs += 1;
                    }
                }
                prev_remote = remote;
                steps.push(s);
            }
            let w = wf(steps);
            let (out, report) = partition_with(&w, batched()).unwrap();
            assert_eq!(report.migration_points, runs);
            let names = |w: &Workflow| {
                let mut v = Vec::new();
                w.root.walk(&mut |s| {
                    if s.kind_name() == "Assign" {
                        v.push(s.display_name.clone());
                    }
                });
                v
            };
            assert_eq!(names(&w), names(&out));
        });
    }
}
