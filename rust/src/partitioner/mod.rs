//! The Emerald partitioner (paper §3.1, Figures 5–6).
//!
//! Input: an *annotated workflow* (steps marked `Remotable="true"`).
//! Output: a *modified workflow with migration points* — a temporary
//! [`StepKind::MigrationPoint`] step inserted immediately **before**
//! each remotable step. At runtime the temporary step suspends the
//! workflow, notifies the migration manager to offload the step, and
//! resumes execution after re-integration (Figure 6).
//!
//! Partitioning validates the three legal-partition properties first
//! ([`crate::workflow::validate`]); any annotated WF workflow that
//! follows the rules can be partitioned.

use anyhow::Result;

use crate::workflow::{validate, Step, StepKind, Workflow};

/// Partitioning statistics.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct PartitionReport {
    /// Number of migration points inserted.
    pub migration_points: usize,
    /// Steps in the workflow before / after.
    pub steps_before: usize,
    pub steps_after: usize,
}

/// Validate and partition a workflow. The input is unchanged; the
/// returned workflow contains the inserted migration points.
pub fn partition(wf: &Workflow) -> Result<(Workflow, PartitionReport)> {
    validate::validate(wf)?;
    let steps_before = wf.size();

    let mut out = wf.clone();
    let mut inserted = 0usize;
    rewrite(&mut out.root, &mut inserted);
    out.renumber();

    Ok((
        out.clone(),
        PartitionReport {
            migration_points: inserted,
            steps_before,
            steps_after: out.size(),
        },
    ))
}

/// Insert migration points in-place.
///
/// * Remotable children of a `Sequence` get a `MigrationPoint` sibling
///   inserted before them.
/// * Remotable children of other containers (`Parallel` branches, `If`
///   branches, `While` bodies) are wrapped in a small `Sequence`
///   [MigrationPoint, step] so the engine's sequence scanner finds
///   them; each parallel branch therefore offloads independently
///   (Figure 9b).
fn rewrite(step: &mut Step, inserted: &mut usize) {
    match &mut step.kind {
        StepKind::Sequence(children) => {
            let mut i = 0;
            while i < children.len() {
                if children[i].remotable {
                    children.insert(i, migration_point());
                    *inserted += 1;
                    // Skip the marker and the (not recursed) remotable
                    // step — P3 guarantees nothing remotable inside it.
                    i += 2;
                } else {
                    rewrite(&mut children[i], inserted);
                    i += 1;
                }
            }
        }
        StepKind::Parallel(children) => {
            for c in children.iter_mut() {
                if c.remotable {
                    wrap_in_sequence(c);
                    *inserted += 1;
                } else {
                    rewrite(c, inserted);
                }
            }
        }
        StepKind::If { then_branch, else_branch, .. } => {
            for b in [Some(then_branch), else_branch.as_mut()].into_iter().flatten() {
                if b.remotable {
                    wrap_in_sequence(b);
                    *inserted += 1;
                } else {
                    rewrite(b, inserted);
                }
            }
        }
        StepKind::While { body, .. } => {
            if body.remotable {
                wrap_in_sequence(body);
                *inserted += 1;
            } else {
                rewrite(body, inserted);
            }
        }
        _ => {}
    }
}

fn migration_point() -> Step {
    Step::new("migration-point", StepKind::MigrationPoint)
}

fn wrap_in_sequence(step: &mut Step) {
    let inner = std::mem::replace(step, Step::new("tmp", StepKind::Nop));
    *step = Step::new(
        format!("offload({})", inner.display_name),
        StepKind::Sequence(vec![migration_point(), inner]),
    );
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quickprop::{forall, Gen};

    fn assign(to: &str, value: &str) -> Step {
        Step::new(to, StepKind::Assign { to: to.into(), value: value.into() })
    }

    fn wf(steps: Vec<Step>) -> Workflow {
        Workflow::new("t", Step::new("main", StepKind::Sequence(steps)))
            .var("a", Some("1"))
            .var("b", Some("2"))
            .var("c", Some("3"))
    }

    #[test]
    fn inserts_point_before_remotable() {
        let w = wf(vec![assign("a", "1"), assign("b", "a + 1").remotable(), assign("c", "b")]);
        let (out, report) = partition(&w).unwrap();
        assert_eq!(report.migration_points, 1);
        assert_eq!(report.steps_after, report.steps_before + 1);
        let kids = out.root.children();
        assert_eq!(kids[1].kind_name(), "MigrationPoint");
        assert_eq!(kids[2].display_name, "b");
    }

    #[test]
    fn wraps_parallel_branches() {
        let w = Workflow::new(
            "p",
            Step::new(
                "main",
                StepKind::Parallel(vec![
                    assign("a", "1").remotable(),
                    assign("b", "2"),
                ]),
            ),
        )
        .var("a", None)
        .var("b", None);
        let (out, report) = partition(&w).unwrap();
        assert_eq!(report.migration_points, 1);
        let branch = out.root.children()[0];
        assert_eq!(branch.kind_name(), "Sequence");
        assert_eq!(branch.children()[0].kind_name(), "MigrationPoint");
        // Non-remotable branch untouched.
        assert_eq!(out.root.children()[1].kind_name(), "Assign");
    }

    #[test]
    fn validation_failures_propagate() {
        let w = wf(vec![assign("a", "1").remotable().local_hardware()]);
        assert!(partition(&w).is_err());
    }

    #[test]
    fn no_remotable_steps_is_identity() {
        let w = wf(vec![assign("a", "1"), assign("b", "2")]);
        let (out, report) = partition(&w).unwrap();
        assert_eq!(report.migration_points, 0);
        assert_eq!(out, w);
    }

    #[test]
    fn idempotent_guard_rejects_repartition() {
        let w = wf(vec![assign("a", "1").remotable()]);
        let (out, _) = partition(&w).unwrap();
        // Partitioning an already-partitioned workflow is an error
        // (validate rejects existing MigrationPoints).
        assert!(partition(&out).is_err());
    }

    #[test]
    fn property_one_point_per_remotable_step() {
        // Random workflows: #migration points == #remotable steps, and
        // the step order is preserved.
        forall(60, |g: &mut Gen| {
            let n = g.usize_in(1..=12);
            let mut steps = Vec::new();
            let mut expect_remote = 0;
            for i in 0..n {
                let mut s = assign(["a", "b", "c"][i % 3], &format!("{i}"));
                if g.bool() {
                    s = s.remotable();
                    expect_remote += 1;
                }
                steps.push(s);
            }
            let w = wf(steps);
            let (out, report) = partition(&w).unwrap();
            assert_eq!(report.migration_points, expect_remote);
            // Order of Assign display names preserved.
            let names = |w: &Workflow| {
                let mut v = Vec::new();
                w.root.walk(&mut |s| {
                    if s.kind_name() == "Assign" {
                        v.push(s.display_name.clone());
                    }
                });
                v
            };
            assert_eq!(names(&w), names(&out));
        });
    }
}
