//! Platform/manager configuration files (substrate: a TOML subset —
//! no toml crate offline).
//!
//! A deployable coordinator needs its testbed parameters in a file,
//! not in code. `emerald ... --platform emerald.toml` loads one:
//!
//! ```toml
//! # emerald.toml
//! [platform]
//! local_nodes = 10
//! local_speed = 1.0
//! # Heterogeneous cloud pool: one entry per tier (price optional,
//! # cost per reference-second of work, default 0.0 = free; boot
//! # optional, provisioning delay in ms charged on the first lease of
//! # a cold VM, default 0).
//! tiers = [{ nodes = 15, speed = 4.0, price = 0.1, boot = 30000 }, { nodes = 10, speed = 8.0 }]
//! # ...or the legacy one-tier shorthand (mutually exclusive):
//! # cloud_nodes = 25
//! # cloud_speed = 4.0
//! # cloud_price = 0.0
//! wan_mbits = 200.0
//! wan_latency_ms = 10
//! schedule = "least-loaded"  # least-loaded | least-loaded-blind | round-robin
//!
//! [engine]
//! dataflow = false         # dependence-DAG scheduling
//! dispatch = "dependency"  # dependency | wavefront (A/B baseline)
//! ir = false               # whole-workflow IR: cross-sequence overlap,
//!                          # ForEach scatter/gather, loop pipelining
//! # workers = 8            # dispatcher worker-pool override (positive
//!                          # integer; absent = max(4, cores))
//!
//! [migration]
//! policy = "mdss"          # mdss | bundle
//! decision = "always"      # always | cost
//! attempts = 1
//! local_fallback = false
//! admission = false        # queue-aware admission control
//! objective = "time"       # time | cost | weighted (placement objective)
//! # weight = 1.0           # seconds per currency unit; only legal
//! #                        # (and only meaningful) with "weighted"
//! # budget = 2.5           # spend cap per manager (= per run in the
//! #                        # CLI; absent = unlimited)
//! # decay_after = 20       # cost-model staleness decay, in offload
//! #                        # attempts (absent = records live forever)
//! steal = false            # idle-VM work stealing
//! resident = true          # cloud-resident data plane: chained
//!                          # offloads pass intermediates by reference
//!                          # (false = ship-every-hop baseline)
//! compress_min = 4096      # payloads below this many bytes skip the
//!                          # wire codec (0 = always compress)
//! signing_key = ""         # non-empty enables request signing
//! codec = "raw"            # raw | deflate
//!
//! [service]                # multi-run service (docs/SERVICE.md)
//! share = "fair"           # fair | fifo — cross-tenant admission
//! # budget = 5.0           # per-tenant spend cap across all of a
//! #                        # tenant's runs (absent = unlimited; the
//! #                        # [migration] budget stays per-run)
//! # weights = { ada = 2.0 }  # fair-share weights (default 1.0)
//!
//! [faults]                 # hostile-cloud model (docs/FAULTS.md)
//! seed = 1337              # seeds the fault AND spot-price streams
//! preempt_rate = 0.25      # P(placement attempt is preempted)
//! # max_preemptions = 8    # cap on injected faults (absent = unbounded)
//! spot_amplitude = 0.5     # relative spot-price excursion (0 = fixed)
//! retries = 2              # retry-elsewhere relocations per offload
//! recover_local = true     # false = fail the run when retries exhaust
//! ```
//!
//! Supported grammar: `[section]` headers, `key = value` with string /
//! number / boolean / inline-array / inline-table values, `#`
//! comments, blank lines.

use std::collections::BTreeMap;
use std::time::Duration;

use anyhow::{bail, Context, Result};

use crate::cloud::{CloudTier, PlatformConfig};
use crate::engine::DataflowDispatch;
use crate::faults::{FaultConfig, FaultPlan};
use crate::mdss::Codec;
use crate::migration::{DataPolicy, Decision, ManagerConfig, SigningKey};
use crate::scheduler::{Objective, SchedulePolicy, SharePolicy, SpotModel};

/// A parsed config file: section -> key -> raw value.
#[derive(Debug, Default, Clone, PartialEq)]
pub struct ConfigFile {
    sections: BTreeMap<String, BTreeMap<String, ConfigValue>>,
}

/// Parsed `[faults]` section — the hostile-cloud model knobs (see
/// `docs/FAULTS.md`). One `seed` drives both the preemption stream
/// and the spot-price stream, so a single number replays the whole
/// scenario.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct FaultsSpec {
    /// `[faults] seed`: seed of the deterministic fault and
    /// spot-price streams.
    pub seed: u64,
    /// `[faults] preempt_rate`: probability in `[0, 1]` that an
    /// offload placement attempt is preempted mid-flight.
    pub preempt_rate: f64,
    /// `[faults] spot_amplitude`: relative amplitude of per-grant
    /// spot-price excursions (`0.0` = fixed base prices).
    pub spot_amplitude: f64,
    /// `[faults] max_preemptions`: cap on total injected preemptions
    /// (`None` = unbounded).
    pub max_preemptions: Option<u64>,
    /// `[faults] retries`: retry-elsewhere relocations per offload.
    pub retries: usize,
    /// `[faults] recover_local`: recover preempted offloads by local
    /// execution when retries exhaust (`false` fails the run).
    pub recover_local: bool,
}

impl Default for FaultsSpec {
    /// The polite cloud: nothing fires, prices stay fixed, and the
    /// recovery knobs match [`ManagerConfig::new`]'s defaults.
    fn default() -> Self {
        Self {
            seed: 0,
            preempt_rate: 0.0,
            spot_amplitude: 0.0,
            max_preemptions: None,
            retries: 2,
            recover_local: true,
        }
    }
}

/// Engine execution options from the `[engine]` section.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct EngineConfig {
    /// `[engine] dataflow`: execute `Sequence` children under a
    /// dependence-DAG schedule
    /// ([`crate::engine::Engine::with_dataflow`]) instead of the
    /// sequential tree-walk. Default `false` (the paper's execution
    /// model, kept as the A/B baseline).
    pub dataflow: bool,
    /// `[engine] dispatch`: which dataflow dispatcher to use —
    /// `"dependency"` (the default; a unit starts the instant its last
    /// dependency finishes) or `"wavefront"` (the barrier-synchronized
    /// baseline). No effect unless `dataflow` is on.
    pub dispatch: DataflowDispatch,
    /// `[engine] ir`: compile the whole workflow into one hazard graph
    /// and execute it with cross-sequence overlap, `ForEach`
    /// scatter/gather and loop-body pipelining
    /// ([`crate::engine::Engine::with_ir`]). Default `false`.
    pub ir: bool,
    /// `[engine] workers`: worker-pool size for the dependency-driven
    /// dispatcher and the IR executor
    /// ([`crate::engine::Engine::with_workers`]). Absent = the
    /// work-conserving default `max(4, available_parallelism)`.
    pub workers: Option<usize>,
}

/// A config value.
#[derive(Debug, Clone, PartialEq)]
pub enum ConfigValue {
    /// Quoted string, e.g. `"mdss"`.
    Str(String),
    /// Number (all numbers parse as `f64`), e.g. `4.0`.
    Num(f64),
    /// Boolean, `true` or `false`.
    Bool(bool),
    /// Inline array, e.g. `[1, 2]` or `[{ nodes = 2, speed = 4.0 }]`.
    Arr(Vec<ConfigValue>),
    /// Inline table, e.g. `{ nodes = 2, speed = 4.0 }`.
    Table(BTreeMap<String, ConfigValue>),
}

impl ConfigValue {
    fn kind(&self) -> &'static str {
        match self {
            ConfigValue::Str(_) => "string",
            ConfigValue::Num(_) => "number",
            ConfigValue::Bool(_) => "boolean",
            ConfigValue::Arr(_) => "array",
            ConfigValue::Table(_) => "table",
        }
    }
}

impl ConfigFile {
    /// Parse config text.
    ///
    /// ```
    /// use emerald::cli::ConfigFile;
    /// use emerald::scheduler::Objective;
    ///
    /// let cfg = ConfigFile::parse(
    ///     r#"
    ///     [platform]
    ///     tiers = [{ nodes = 2, speed = 2.0, price = 0.5 }]
    ///     [migration]
    ///     objective = "cost"
    ///     budget = 1.5
    ///     "#,
    /// )?;
    /// assert_eq!(cfg.platform()?.tiers[0].price, 0.5);
    /// let migration = cfg.migration()?;
    /// assert_eq!(migration.objective, Objective::Cost);
    /// assert_eq!(migration.budget, Some(1.5));
    /// # Ok::<(), anyhow::Error>(())
    /// ```
    pub fn parse(text: &str) -> Result<Self> {
        let mut out = Self::default();
        let mut section = String::new();
        for (lineno, raw) in text.lines().enumerate() {
            let line = raw.split('#').next().unwrap_or("").trim();
            if line.is_empty() {
                continue;
            }
            if let Some(name) = line.strip_prefix('[').and_then(|s| s.strip_suffix(']')) {
                section = name.trim().to_string();
                if section.is_empty() {
                    bail!("line {}: empty section name", lineno + 1);
                }
                out.sections.entry(section.clone()).or_default();
                continue;
            }
            let Some((key, value)) = line.split_once('=') else {
                bail!("line {}: expected `key = value`, got {line:?}", lineno + 1);
            };
            let key = key.trim().to_string();
            let value = Self::parse_value(value.trim())
                .with_context(|| format!("line {}: value for {key}", lineno + 1))?;
            out.sections.entry(section.clone()).or_default().insert(key, value);
        }
        Ok(out)
    }

    /// Nesting ceiling for inline values — far beyond any legitimate
    /// config, but keeps a pathological input a parse error instead of
    /// a stack overflow.
    const MAX_VALUE_DEPTH: usize = 32;

    fn parse_value(raw: &str) -> Result<ConfigValue> {
        let (value, rest) = Self::parse_value_inner(raw, 0)?;
        if !rest.trim().is_empty() {
            bail!("trailing characters after value: {rest:?}");
        }
        Ok(value)
    }

    /// Recursive-descent value parser: scalars, `[a, b]` arrays and
    /// `{ k = v, ... }` inline tables (the TOML subset `tiers` needs).
    /// Returns the value and the unconsumed remainder of the input.
    fn parse_value_inner(raw: &str, depth: usize) -> Result<(ConfigValue, &str)> {
        if depth > Self::MAX_VALUE_DEPTH {
            bail!("value nested deeper than {} levels", Self::MAX_VALUE_DEPTH);
        }
        let s = raw.trim_start();
        if let Some(mut rest) = s.strip_prefix('[') {
            let mut items = Vec::new();
            loop {
                rest = rest.trim_start();
                if let Some(r) = rest.strip_prefix(']') {
                    return Ok((ConfigValue::Arr(items), r));
                }
                if !items.is_empty() {
                    rest = rest
                        .strip_prefix(',')
                        .context("expected ',' or ']' in array")?
                        .trim_start();
                    // Trailing comma before the closing bracket.
                    if let Some(r) = rest.strip_prefix(']') {
                        return Ok((ConfigValue::Arr(items), r));
                    }
                }
                let (item, r) = Self::parse_value_inner(rest, depth + 1)?;
                items.push(item);
                rest = r;
            }
        }
        if let Some(mut rest) = s.strip_prefix('{') {
            let mut map = BTreeMap::new();
            loop {
                rest = rest.trim_start();
                if let Some(r) = rest.strip_prefix('}') {
                    return Ok((ConfigValue::Table(map), r));
                }
                if !map.is_empty() {
                    rest = rest
                        .strip_prefix(',')
                        .context("expected ',' or '}' in inline table")?
                        .trim_start();
                    if let Some(r) = rest.strip_prefix('}') {
                        return Ok((ConfigValue::Table(map), r));
                    }
                }
                let eq = rest
                    .find('=')
                    .context("expected `key = value` in inline table")?;
                let key = rest[..eq].trim().to_string();
                if key.is_empty() || key.contains(|c: char| "{}[],\"".contains(c)) {
                    bail!("bad inline-table key {key:?}");
                }
                let (value, r) = Self::parse_value_inner(&rest[eq + 1..], depth + 1)?;
                map.insert(key, value);
                rest = r;
            }
        }
        if let Some(rest) = s.strip_prefix('"') {
            let end = rest.find('"').context("unterminated string")?;
            return Ok((ConfigValue::Str(rest[..end].to_string()), &rest[end + 1..]));
        }
        // Bare scalar: runs until a structural delimiter or the end.
        let end = s
            .find(|c: char| c == ',' || c == ']' || c == '}')
            .unwrap_or(s.len());
        let token = s[..end].trim();
        let value = match token {
            "true" => ConfigValue::Bool(true),
            "false" => ConfigValue::Bool(false),
            _ => token
                .parse::<f64>()
                .map(ConfigValue::Num)
                .map_err(|_| anyhow::anyhow!("cannot parse value {token:?}"))?,
        };
        Ok((value, &s[end..]))
    }

    /// Load from a file path.
    pub fn load(path: impl AsRef<std::path::Path>) -> Result<Self> {
        let path = path.as_ref();
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading config {}", path.display()))?;
        Self::parse(&text).with_context(|| format!("parsing {}", path.display()))
    }

    fn get(&self, section: &str, key: &str) -> Option<&ConfigValue> {
        self.sections.get(section)?.get(key)
    }

    fn num(&self, section: &str, key: &str, default: f64) -> Result<f64> {
        match self.get(section, key) {
            None => Ok(default),
            Some(ConfigValue::Num(n)) => Ok(*n),
            Some(v) => bail!("[{section}] {key} must be a number, got {}", v.kind()),
        }
    }

    fn string(&self, section: &str, key: &str, default: &str) -> Result<String> {
        match self.get(section, key) {
            None => Ok(default.to_string()),
            Some(ConfigValue::Str(s)) => Ok(s.clone()),
            Some(v) => bail!("[{section}] {key} must be a string, got {}", v.kind()),
        }
    }

    fn boolean(&self, section: &str, key: &str, default: bool) -> Result<bool> {
        match self.get(section, key) {
            None => Ok(default),
            Some(ConfigValue::Bool(b)) => Ok(*b),
            Some(v) => bail!("[{section}] {key} must be a boolean, got {}", v.kind()),
        }
    }

    /// Cloud tiers from the `[platform]` section: either an explicit
    /// `tiers = [{ nodes = N, speed = S, price = P }, ...]` array
    /// (`price` optional, default 0.0 = free) or the legacy one-tier
    /// `cloud_nodes`/`cloud_speed`/`cloud_price` shorthand (mutually
    /// exclusive; legacy configs parse unchanged).
    fn cloud_tiers(&self, default: &[CloudTier]) -> Result<Vec<CloudTier>> {
        let legacy = self.get("platform", "cloud_nodes").is_some()
            || self.get("platform", "cloud_speed").is_some()
            || self.get("platform", "cloud_price").is_some();
        match self.get("platform", "tiers") {
            // No cloud keys at all: keep the full default tier list.
            None if !legacy => Ok(default.to_vec()),
            None => {
                let d = default.first().copied().unwrap_or(CloudTier::new(0, 1.0));
                Ok(vec![CloudTier::priced(
                    self.num("platform", "cloud_nodes", d.nodes as f64)? as usize,
                    self.num("platform", "cloud_speed", d.speed)?,
                    self.num("platform", "cloud_price", d.price)?,
                )])
            }
            Some(_) if legacy => {
                bail!(
                    "[platform] tiers cannot be combined with \
                     cloud_nodes/cloud_speed/cloud_price"
                )
            }
            Some(ConfigValue::Arr(items)) => {
                let mut tiers = Vec::with_capacity(items.len());
                for (i, item) in items.iter().enumerate() {
                    let ConfigValue::Table(t) = item else {
                        bail!(
                            "[platform] tiers[{i}] must be an inline table \
                             {{ nodes = N, speed = S, price = P }}, got {}",
                            item.kind()
                        );
                    };
                    for key in t.keys() {
                        if key != "nodes" && key != "speed" && key != "price" && key != "boot" {
                            bail!("[platform] tiers[{i}]: unknown key {key:?}");
                        }
                    }
                    let nodes = match t.get("nodes") {
                        Some(ConfigValue::Num(n)) if *n >= 0.0 && n.fract() == 0.0 => {
                            *n as usize
                        }
                        Some(ConfigValue::Num(n)) => bail!(
                            "[platform] tiers[{i}].nodes must be a non-negative integer, got {n}"
                        ),
                        Some(v) => {
                            bail!("[platform] tiers[{i}].nodes must be a number, got {}", v.kind())
                        }
                        None => bail!("[platform] tiers[{i}] is missing `nodes`"),
                    };
                    let speed = match t.get("speed") {
                        Some(ConfigValue::Num(s)) => *s,
                        Some(v) => {
                            bail!("[platform] tiers[{i}].speed must be a number, got {}", v.kind())
                        }
                        None => bail!("[platform] tiers[{i}] is missing `speed`"),
                    };
                    let price = match t.get("price") {
                        Some(ConfigValue::Num(p)) => *p,
                        Some(v) => {
                            bail!("[platform] tiers[{i}].price must be a number, got {}", v.kind())
                        }
                        None => 0.0,
                    };
                    let boot = match t.get("boot") {
                        Some(ConfigValue::Num(ms)) if ms.is_finite() && *ms >= 0.0 => {
                            Duration::from_secs_f64(*ms / 1e3)
                        }
                        Some(ConfigValue::Num(ms)) => bail!(
                            "[platform] tiers[{i}].boot must be a non-negative number \
                             of milliseconds, got {ms}"
                        ),
                        Some(v) => {
                            bail!("[platform] tiers[{i}].boot must be a number, got {}", v.kind())
                        }
                        None => Duration::ZERO,
                    };
                    tiers.push(CloudTier::priced(nodes, speed, price).with_boot(boot));
                }
                Ok(tiers)
            }
            Some(v) => bail!("[platform] tiers must be an array of tables, got {}", v.kind()),
        }
    }

    /// Build a [`PlatformConfig`] from the `[platform]` section
    /// (missing keys take paper defaults).
    pub fn platform(&self) -> Result<PlatformConfig> {
        let d = PlatformConfig::default();
        let schedule = match self.string("platform", "schedule", "least-loaded")?.as_str() {
            "least-loaded" => SchedulePolicy::LeastLoaded,
            "least-loaded-blind" => SchedulePolicy::LeastLoadedBlind,
            "round-robin" => SchedulePolicy::RoundRobin,
            other => {
                bail!(
                    "[platform] schedule must be least-loaded|least-loaded-blind|round-robin, \
                     got {other:?}"
                )
            }
        };
        Ok(PlatformConfig {
            local_nodes: self.num("platform", "local_nodes", d.local_nodes as f64)? as usize,
            local_speed: self.num("platform", "local_speed", d.local_speed)?,
            tiers: self.cloud_tiers(&d.tiers)?,
            wan_bandwidth: self.num("platform", "wan_mbits", d.wan_bandwidth * 8.0 / 1e6)?
                * 1e6
                / 8.0,
            wan_latency: Duration::from_secs_f64(
                self.num("platform", "wan_latency_ms", d.wan_latency.as_secs_f64() * 1e3)?
                    / 1e3,
            ),
            schedule,
            // Spot-price dynamics ride on the `[faults]` seed so one
            // number replays the whole hostile-cloud scenario.
            spot: {
                let f = self.faults()?;
                (f.spot_amplitude > 0.0).then(|| SpotModel::new(f.seed, f.spot_amplitude))
            },
        })
    }

    /// Parse the `[faults]` section — the hostile-cloud model (see
    /// `docs/FAULTS.md`). An absent section yields the polite-cloud
    /// default: nothing fires, prices stay fixed.
    pub fn faults(&self) -> Result<FaultsSpec> {
        let d = FaultsSpec::default();
        let seed = match self.get("faults", "seed") {
            None => d.seed,
            Some(ConfigValue::Num(n)) if *n >= 0.0 && n.fract() == 0.0 => *n as u64,
            Some(ConfigValue::Num(n)) => {
                bail!("[faults] seed must be a non-negative integer, got {n}")
            }
            Some(v) => bail!("[faults] seed must be a number, got {}", v.kind()),
        };
        let preempt_rate = self.num("faults", "preempt_rate", d.preempt_rate)?;
        if !(0.0..=1.0).contains(&preempt_rate) {
            bail!("[faults] preempt_rate must be in [0, 1], got {preempt_rate}");
        }
        let spot_amplitude = self.num("faults", "spot_amplitude", d.spot_amplitude)?;
        if !spot_amplitude.is_finite() || spot_amplitude < 0.0 {
            bail!(
                "[faults] spot_amplitude must be a non-negative finite number, \
                 got {spot_amplitude}"
            );
        }
        let max_preemptions = match self.get("faults", "max_preemptions") {
            None => None,
            Some(ConfigValue::Num(n)) if *n >= 0.0 && n.fract() == 0.0 => Some(*n as u64),
            Some(ConfigValue::Num(n)) => {
                bail!("[faults] max_preemptions must be a non-negative integer, got {n}")
            }
            Some(v) => bail!("[faults] max_preemptions must be a number, got {}", v.kind()),
        };
        let retries = match self.get("faults", "retries") {
            None => d.retries,
            Some(ConfigValue::Num(n)) if *n >= 0.0 && n.fract() == 0.0 => *n as usize,
            Some(ConfigValue::Num(n)) => {
                bail!("[faults] retries must be a non-negative integer, got {n}")
            }
            Some(v) => bail!("[faults] retries must be a number, got {}", v.kind()),
        };
        Ok(FaultsSpec {
            seed,
            preempt_rate,
            spot_amplitude,
            max_preemptions,
            retries,
            recover_local: self.boolean("faults", "recover_local", d.recover_local)?,
        })
    }

    /// Build an [`EngineConfig`] from the `[engine]` section (missing
    /// keys take the sequential-engine defaults).
    pub fn engine(&self) -> Result<EngineConfig> {
        let dispatch = match self.string("engine", "dispatch", "dependency")?.as_str() {
            "dependency" => DataflowDispatch::Dependency,
            "wavefront" => DataflowDispatch::Wavefront,
            other => bail!("[engine] dispatch must be dependency|wavefront, got {other:?}"),
        };
        let workers = match self.get("engine", "workers") {
            None => None,
            Some(ConfigValue::Num(n)) if *n >= 1.0 && n.fract() == 0.0 => Some(*n as usize),
            Some(ConfigValue::Num(n)) => {
                bail!("[engine] workers must be a positive integer, got {n}")
            }
            Some(v) => bail!("[engine] workers must be a number, got {}", v.kind()),
        };
        Ok(EngineConfig {
            dataflow: self.boolean("engine", "dataflow", false)?,
            dispatch,
            ir: self.boolean("engine", "ir", false)?,
            workers,
        })
    }

    /// Build a [`ManagerConfig`] from the `[migration]` section.
    pub fn migration(&self) -> Result<ManagerConfig> {
        let policy = match self.string("migration", "policy", "mdss")?.as_str() {
            "mdss" => DataPolicy::Mdss,
            "bundle" => DataPolicy::BundleAlways,
            other => bail!("[migration] policy must be mdss|bundle, got {other:?}"),
        };
        let mut cfg = ManagerConfig::new(policy);
        cfg.decision = match self.string("migration", "decision", "always")?.as_str() {
            "always" => Decision::Always,
            "cost" => Decision::CostBased,
            other => bail!("[migration] decision must be always|cost, got {other:?}"),
        };
        cfg.attempts = self.num("migration", "attempts", 1.0)? as usize;
        cfg.local_fallback = self.boolean("migration", "local_fallback", false)?;
        cfg.admission = self.boolean("migration", "admission", false)?;
        cfg.steal = self.boolean("migration", "steal", false)?;
        let objective = self.string("migration", "objective", "time")?;
        let weight_present = self.get("migration", "weight").is_some();
        cfg.objective = match objective.as_str() {
            "time" => Objective::Time,
            "cost" => Objective::Cost,
            "weighted" => {
                let w = self.num("migration", "weight", 1.0)?;
                if !w.is_finite() || w < 0.0 {
                    bail!(
                        "[migration] weight must be a non-negative finite number, got {w}"
                    );
                }
                Objective::Weighted(w)
            }
            other => bail!("[migration] objective must be time|cost|weighted, got {other:?}"),
        };
        if weight_present && !matches!(cfg.objective, Objective::Weighted(_)) {
            bail!("[migration] weight is only meaningful with objective = \"weighted\"");
        }
        cfg.budget = match self.get("migration", "budget") {
            None => None,
            Some(ConfigValue::Num(b)) if b.is_finite() && *b >= 0.0 => Some(*b),
            Some(ConfigValue::Num(b)) => {
                bail!("[migration] budget must be a non-negative finite number, got {b}")
            }
            Some(v) => bail!("[migration] budget must be a number, got {}", v.kind()),
        };
        cfg.decay_after = match self.get("migration", "decay_after") {
            None => None,
            Some(ConfigValue::Num(n)) if *n >= 1.0 && n.fract() == 0.0 => Some(*n as u64),
            Some(ConfigValue::Num(n)) => {
                bail!("[migration] decay_after must be a positive integer, got {n}")
            }
            Some(v) => bail!("[migration] decay_after must be a number, got {}", v.kind()),
        };
        cfg.resident = self.boolean("migration", "resident", cfg.resident)?;
        cfg.compress_min = match self.get("migration", "compress_min") {
            None => cfg.compress_min,
            Some(ConfigValue::Num(n)) if *n >= 0.0 && n.fract() == 0.0 => *n as u64,
            Some(ConfigValue::Num(n)) => {
                bail!("[migration] compress_min must be a non-negative integer, got {n}")
            }
            Some(v) => bail!("[migration] compress_min must be a number, got {}", v.kind()),
        };
        let key = self.string("migration", "signing_key", "")?;
        if !key.is_empty() {
            cfg.signing = Some(SigningKey::new(key.into_bytes()));
        }
        // Hostile-cloud knobs ride in from `[faults]`: a fresh
        // FaultPlan per manager (plans hold attempt counters, so
        // sharing one across runs would shift the stream).
        let f = self.faults()?;
        cfg.preempt_retries = f.retries;
        cfg.preempt_local = f.recover_local;
        if f.preempt_rate > 0.0 {
            cfg.faults = Some(FaultPlan::new(FaultConfig {
                seed: f.seed,
                preempt_rate: f.preempt_rate,
                max_preemptions: f.max_preemptions,
            })?);
        }
        Ok(cfg)
    }

    /// Build a [`crate::service::ServiceConfig`] from the `[service]`
    /// section. The per-run manager template comes from `[migration]`
    /// and the execution mode from `[engine]`, so one file configures
    /// the whole multi-run service (see `docs/SERVICE.md`).
    pub fn service(&self) -> Result<crate::service::ServiceConfig> {
        let mut cfg = crate::service::ServiceConfig::new();
        cfg.manager = self.migration()?;
        let engine = self.engine()?;
        cfg.dataflow = engine.dataflow;
        cfg.ir = engine.ir;
        cfg.share = match self.string("service", "share", "fair")?.as_str() {
            "fair" => SharePolicy::FairShare,
            "fifo" => SharePolicy::Fifo,
            other => bail!("[service] share must be fair|fifo, got {other:?}"),
        };
        cfg.tenant_budget = match self.get("service", "budget") {
            None => None,
            Some(ConfigValue::Num(b)) if b.is_finite() && *b >= 0.0 => Some(*b),
            Some(ConfigValue::Num(b)) => {
                bail!("[service] budget must be a non-negative finite number, got {b}")
            }
            Some(v) => bail!("[service] budget must be a number, got {}", v.kind()),
        };
        cfg.weights = match self.get("service", "weights") {
            None => Vec::new(),
            Some(ConfigValue::Table(t)) => {
                let mut out = Vec::new();
                for (tenant, v) in t {
                    match v {
                        ConfigValue::Num(w) if w.is_finite() && *w > 0.0 => {
                            out.push((tenant.clone(), *w))
                        }
                        ConfigValue::Num(w) => {
                            bail!(
                                "[service] weights.{tenant} must be positive and finite, got {w}"
                            )
                        }
                        v => bail!(
                            "[service] weights.{tenant} must be a number, got {}",
                            v.kind()
                        ),
                    }
                }
                out
            }
            Some(v) => bail!("[service] weights must be an inline table, got {}", v.kind()),
        };
        Ok(cfg)
    }

    /// MDSS wire codec from the `[migration]` section.
    pub fn codec(&self) -> Result<Codec> {
        match self.string("migration", "codec", "raw")?.as_str() {
            "raw" => Ok(Codec::Raw),
            "deflate" => Ok(Codec::Deflate),
            other => bail!("[migration] codec must be raw|deflate, got {other:?}"),
        }
    }

    /// Every key each section accepts. The accessors above ignore
    /// anything else, so without a strict pass a misspelled key (e.g.
    /// `bugdet = 5.0`) silently falls back to its default — a run that
    /// was meant to be capped runs uncapped.
    const KNOWN_KEYS: &'static [(&'static str, &'static [&'static str])] = &[
        (
            "platform",
            &[
                "local_nodes",
                "local_speed",
                "tiers",
                "cloud_nodes",
                "cloud_speed",
                "cloud_price",
                "wan_mbits",
                "wan_latency_ms",
                "schedule",
            ],
        ),
        ("engine", &["dataflow", "dispatch", "ir", "workers"]),
        ("service", &["share", "budget", "weights"]),
        (
            "migration",
            &[
                "policy",
                "decision",
                "attempts",
                "local_fallback",
                "admission",
                "steal",
                "objective",
                "weight",
                "budget",
                "decay_after",
                "signing_key",
                "codec",
                "resident",
                "compress_min",
            ],
        ),
        (
            "faults",
            &[
                "seed",
                "preempt_rate",
                "spot_amplitude",
                "max_preemptions",
                "retries",
                "recover_local",
            ],
        ),
    ];

    /// Does the file set `[section] key` explicitly?
    pub fn contains(&self, section: &str, key: &str) -> bool {
        self.get(section, key).is_some()
    }

    /// All unknown sections and unknown keys inside known sections,
    /// each with a nearest-known did-you-mean suggestion. Empty for a
    /// clean file. [`ConfigFile::check_keys`] turns the first entry
    /// into a hard error; `emerald check` reports all of them as
    /// lint findings.
    pub fn unknown_entries(&self) -> Vec<UnknownKey> {
        let mut out = Vec::new();
        let section_names: Vec<&str> =
            Self::KNOWN_KEYS.iter().map(|(s, _)| *s).collect();
        for (section, keys) in &self.sections {
            match Self::KNOWN_KEYS.iter().find(|(s, _)| s == section) {
                None => out.push(UnknownKey {
                    section: section.clone(),
                    key: None,
                    suggestion: nearest(section, &section_names),
                }),
                Some((_, known)) => {
                    for key in keys.keys() {
                        if !known.contains(&key.as_str()) {
                            out.push(UnknownKey {
                                section: section.clone(),
                                key: Some(key.clone()),
                                suggestion: nearest(key, known),
                            });
                        }
                    }
                }
            }
        }
        out
    }

    /// Reject unknown sections/keys with a did-you-mean diagnostic.
    /// Called on every CLI config load, so a typo fails fast instead
    /// of silently running with defaults.
    pub fn check_keys(&self) -> Result<()> {
        if let Some(bad) = self.unknown_entries().into_iter().next() {
            bail!("{}", bad.message());
        }
        Ok(())
    }
}

/// One unknown config entry found by [`ConfigFile::unknown_entries`].
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct UnknownKey {
    /// The section the entry appeared in (or the unknown section
    /// name itself when `key` is `None`).
    pub section: String,
    /// The unknown key, or `None` when the whole section is unknown.
    pub key: Option<String>,
    /// Closest known key/section name, when one is plausibly close.
    pub suggestion: Option<String>,
}

impl UnknownKey {
    /// Human-readable one-line diagnostic.
    pub fn message(&self) -> String {
        let mut msg = match &self.key {
            Some(key) => format!("[{}] unknown key `{key}`", self.section),
            None => format!("unknown config section [{}]", self.section),
        };
        if let Some(s) = &self.suggestion {
            msg.push_str(&format!("; did you mean `{s}`?"));
        }
        msg
    }
}

/// Closest candidate within a small edit distance (did-you-mean).
fn nearest(word: &str, candidates: &[&str]) -> Option<String> {
    let budget = 2.max(word.len() / 3);
    candidates
        .iter()
        .map(|c| (levenshtein(word, c), *c))
        .filter(|(d, _)| *d <= budget)
        .min()
        .map(|(_, c)| c.to_string())
}

/// Classic two-row Levenshtein edit distance.
fn levenshtein(a: &str, b: &str) -> usize {
    let a: Vec<char> = a.chars().collect();
    let b: Vec<char> = b.chars().collect();
    let mut prev: Vec<usize> = (0..=b.len()).collect();
    let mut cur = vec![0; b.len() + 1];
    for (i, ca) in a.iter().enumerate() {
        cur[0] = i + 1;
        for (j, cb) in b.iter().enumerate() {
            let sub = prev[j] + usize::from(ca != cb);
            cur[j + 1] = sub.min(prev[j + 1] + 1).min(cur[j] + 1);
        }
        std::mem::swap(&mut prev, &mut cur);
    }
    prev[b.len()]
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"
        # testbed
        [platform]
        local_nodes = 4
        cloud_speed = 2.5
        wan_mbits = 100.0
        wan_latency_ms = 5

        [migration]
        policy = "bundle"
        decision = "cost"
        attempts = 3
        local_fallback = true
        signing_key = "secret"
        codec = "deflate"
    "#;

    #[test]
    fn parses_platform_with_defaults() {
        // Legacy one-tier configs parse unchanged into a single tier.
        let cfg = ConfigFile::parse(SAMPLE).unwrap();
        let p = cfg.platform().unwrap();
        assert_eq!(p.local_nodes, 4);
        assert_eq!(p.cloud_nodes(), 25); // default node count kept
        assert_eq!(p.tiers, vec![crate::cloud::CloudTier::new(25, 2.5)]);
        assert_eq!(p.wan_bandwidth, 100.0e6 / 8.0);
        assert_eq!(p.wan_latency, Duration::from_millis(5));
        assert_eq!(p.schedule, SchedulePolicy::LeastLoaded); // default kept
    }

    #[test]
    fn parses_heterogeneous_tiers() {
        let cfg = ConfigFile::parse(
            "[platform]\ntiers = [{ nodes = 15, speed = 4.0 }, { nodes = 10, speed = 8.0 }]",
        )
        .unwrap();
        let p = cfg.platform().unwrap();
        assert_eq!(
            p.tiers,
            vec![
                crate::cloud::CloudTier::new(15, 4.0),
                crate::cloud::CloudTier::new(10, 8.0)
            ]
        );
        assert_eq!(p.cloud_nodes(), 25);
        // Zero-cloud via an empty array.
        let cfg = ConfigFile::parse("[platform]\ntiers = []").unwrap();
        assert_eq!(cfg.platform().unwrap().cloud_nodes(), 0);
    }

    #[test]
    fn tiers_reject_conflicts_and_malformed_entries() {
        for bad in [
            // tiers and the legacy shorthand are mutually exclusive
            "[platform]\ncloud_nodes = 2\ntiers = [{ nodes = 1, speed = 2.0 }]",
            "[platform]\ncloud_price = 0.5\ntiers = [{ nodes = 1, speed = 2.0 }]",
            "[platform]\ntiers = [{ nodes = 1 }]",            // missing speed
            "[platform]\ntiers = [{ speed = 2.0 }]",          // missing nodes
            "[platform]\ntiers = [{ nodes = -5, speed = 4.0 }]", // negative count
            "[platform]\ntiers = [{ nodes = 2.7, speed = 4.0 }]", // fractional count
            "[platform]\ntiers = [{ nodes = 1, speed = 2.0, vram = 80 }]", // unknown key
            "[platform]\ntiers = [4.0]",                      // not a table
            "[platform]\ntiers = { nodes = 1, speed = 2.0 }", // not an array
            "[platform]\ntiers = [{ nodes = 1, speed = \"fast\" }]", // wrong type
            "[platform]\ntiers = [{ nodes = 1, speed = 2.0, price = \"cheap\" }]",
        ] {
            let cfg = ConfigFile::parse(bad).unwrap();
            assert!(cfg.platform().is_err(), "should reject {bad:?}");
        }
    }

    #[test]
    fn parses_tier_prices() {
        let cfg = ConfigFile::parse(
            "[platform]\ntiers = [{ nodes = 2, speed = 2.0, price = 0.5 }, \
             { nodes = 1, speed = 8.0 }]",
        )
        .unwrap();
        let p = cfg.platform().unwrap();
        assert_eq!(
            p.tiers,
            vec![
                crate::cloud::CloudTier::priced(2, 2.0, 0.5),
                crate::cloud::CloudTier::new(1, 8.0), // price defaults to free
            ]
        );
        // Legacy shorthand with a price.
        let cfg =
            ConfigFile::parse("[platform]\ncloud_nodes = 3\ncloud_price = 0.25").unwrap();
        let p = cfg.platform().unwrap();
        assert_eq!(p.tiers, vec![crate::cloud::CloudTier::priced(3, 4.0, 0.25)]);
    }

    #[test]
    fn parses_objective_budget_and_steal() {
        let cfg = ConfigFile::parse(
            "[migration]\nobjective = \"cost\"\nbudget = 2.5\nsteal = true",
        )
        .unwrap();
        let m = cfg.migration().unwrap();
        assert_eq!(m.objective, Objective::Cost);
        assert_eq!(m.budget, Some(2.5));
        assert!(m.steal);
        let cfg =
            ConfigFile::parse("[migration]\nobjective = \"weighted\"\nweight = 0.5").unwrap();
        assert_eq!(cfg.migration().unwrap().objective, Objective::Weighted(0.5));
        // Defaults: time objective, weight 1.0 when weighted, no
        // budget, no stealing.
        let cfg = ConfigFile::parse("[migration]\nobjective = \"weighted\"").unwrap();
        assert_eq!(cfg.migration().unwrap().objective, Objective::Weighted(1.0));
        let m = ConfigFile::parse("").unwrap().migration().unwrap();
        assert_eq!(m.objective, Objective::Time);
        assert_eq!(m.budget, None);
        assert!(!m.steal);
        // Rejections.
        for bad in [
            "[migration]\nobjective = \"money\"",
            "[migration]\nbudget = -1.0",
            "[migration]\nbudget = \"lots\"",
            "[migration]\nweight = 0.5", // weight without weighted
            "[migration]\nobjective = \"weighted\"\nweight = -2.0",
        ] {
            let cfg = ConfigFile::parse(bad).unwrap();
            assert!(cfg.migration().is_err(), "should reject {bad:?}");
        }
    }

    #[test]
    fn parses_resident_and_compress_min() {
        // Defaults: residency on, 4 KiB compression cutoff.
        let m = ConfigFile::parse("").unwrap().migration().unwrap();
        assert!(m.resident);
        assert_eq!(m.compress_min, 4096);
        let cfg =
            ConfigFile::parse("[migration]\nresident = false\ncompress_min = 0").unwrap();
        let m = cfg.migration().unwrap();
        assert!(!m.resident);
        assert_eq!(m.compress_min, 0);
        // Rejections.
        for bad in [
            "[migration]\nresident = 1",
            "[migration]\ncompress_min = -1",
            "[migration]\ncompress_min = 2.5",
            "[migration]\ncompress_min = \"big\"",
        ] {
            let cfg = ConfigFile::parse(bad).unwrap();
            assert!(cfg.migration().is_err(), "should reject {bad:?}");
        }
    }

    #[test]
    fn parses_engine_section_and_decay() {
        // Defaults: sequential engine, dependency dispatch, no decay.
        let cfg = ConfigFile::parse("").unwrap();
        assert!(!cfg.engine().unwrap().dataflow);
        assert_eq!(cfg.engine().unwrap().dispatch, DataflowDispatch::Dependency);
        assert_eq!(cfg.migration().unwrap().decay_after, None);
        let cfg = ConfigFile::parse("[engine]\ndataflow = true").unwrap();
        assert!(cfg.engine().unwrap().dataflow);
        let cfg = ConfigFile::parse("[engine]\ndispatch = \"wavefront\"").unwrap();
        assert_eq!(cfg.engine().unwrap().dispatch, DataflowDispatch::Wavefront);
        let cfg = ConfigFile::parse("[engine]\ndispatch = \"dependency\"").unwrap();
        assert_eq!(cfg.engine().unwrap().dispatch, DataflowDispatch::Dependency);
        let cfg = ConfigFile::parse("[engine]\ndispatch = \"barrier\"").unwrap();
        assert!(cfg.engine().is_err(), "unknown dispatch must be rejected");
        let cfg = ConfigFile::parse("[migration]\ndecay_after = 20").unwrap();
        assert_eq!(cfg.migration().unwrap().decay_after, Some(20));
        // Whole-workflow IR mode and the worker-pool override.
        let cfg = ConfigFile::parse("").unwrap();
        assert!(!cfg.engine().unwrap().ir);
        assert_eq!(cfg.engine().unwrap().workers, None);
        let cfg = ConfigFile::parse("[engine]\nir = true\nworkers = 8").unwrap();
        assert!(cfg.engine().unwrap().ir);
        assert_eq!(cfg.engine().unwrap().workers, Some(8));
        // Rejections.
        let cfg = ConfigFile::parse("[engine]\ndataflow = 1").unwrap();
        assert!(cfg.engine().is_err());
        for bad in [
            "[engine]\nworkers = 0",
            "[engine]\nworkers = 2.5",
            "[engine]\nworkers = -1",
            "[engine]\nworkers = \"many\"",
            "[engine]\nir = 1",
        ] {
            let cfg = ConfigFile::parse(bad).unwrap();
            assert!(cfg.engine().is_err(), "should reject {bad:?}");
        }
        for bad in [
            "[migration]\ndecay_after = 0",
            "[migration]\ndecay_after = 2.5",
            "[migration]\ndecay_after = -3",
            "[migration]\ndecay_after = \"often\"",
        ] {
            let cfg = ConfigFile::parse(bad).unwrap();
            assert!(cfg.migration().is_err(), "should reject {bad:?}");
        }
    }

    #[test]
    fn inline_value_grammar() {
        // Nested arrays/tables round-trip through the value parser.
        let cfg = ConfigFile::parse("[x]\na = [1, 2, 3]\nb = [{ k = \"v\" }, true,]").unwrap();
        assert_eq!(
            cfg.get("x", "a"),
            Some(&ConfigValue::Arr(vec![
                ConfigValue::Num(1.0),
                ConfigValue::Num(2.0),
                ConfigValue::Num(3.0)
            ]))
        );
        let Some(ConfigValue::Arr(items)) = cfg.get("x", "b") else {
            panic!("expected array");
        };
        assert_eq!(items.len(), 2);
        assert_eq!(items[1], ConfigValue::Bool(true));
        // Malformed nestings are rejected.
        for bad in ["[x]\na = [1", "[x]\na = { k }", "[x]\na = [1] trailing", "[x]\na = { = 1 }"]
        {
            assert!(ConfigFile::parse(bad).is_err(), "should reject {bad:?}");
        }
        // Pathological nesting is a parse error, not a stack overflow.
        let deep = format!("[x]\na = {}1{}", "[".repeat(100_000), "]".repeat(100_000));
        assert!(ConfigFile::parse(&deep).is_err());
    }

    #[test]
    fn parses_service_section() {
        // Defaults: fair share, no tenant budget, no weights, and the
        // [migration]/[engine] sections feed the templates.
        let cfg = ConfigFile::parse("").unwrap();
        let s = cfg.service().unwrap();
        assert_eq!(s.share, SharePolicy::FairShare);
        assert_eq!(s.tenant_budget, None);
        assert!(s.weights.is_empty());
        assert!(!s.dataflow && !s.ir);
        let cfg = ConfigFile::parse(
            "[engine]\ndataflow = true\n\
             [migration]\nbudget = 1.5\n\
             [service]\nshare = \"fifo\"\nbudget = 5.0\nweights = { ada = 2.0, grace = 1.0 }",
        )
        .unwrap();
        let s = cfg.service().unwrap();
        assert_eq!(s.share, SharePolicy::Fifo);
        assert_eq!(s.tenant_budget, Some(5.0));
        assert_eq!(
            s.weights,
            vec![("ada".to_string(), 2.0), ("grace".to_string(), 1.0)]
        );
        assert!(s.dataflow);
        assert_eq!(s.manager.budget, Some(1.5), "per-run budget rides in from [migration]");
        assert!(cfg.check_keys().is_ok(), "[service] keys must be known");
        // Rejections.
        for bad in [
            "[service]\nshare = \"priority\"",
            "[service]\nbudget = -1.0",
            "[service]\nbudget = \"lots\"",
            "[service]\nweights = { ada = 0.0 }",
            "[service]\nweights = { ada = \"high\" }",
            "[service]\nweights = [1.0]",
        ] {
            let cfg = ConfigFile::parse(bad).unwrap();
            assert!(cfg.service().is_err(), "should reject {bad:?}");
        }
    }

    #[test]
    fn parses_schedule_policy() {
        let cfg =
            ConfigFile::parse("[platform]\nschedule = \"round-robin\"").unwrap();
        assert_eq!(cfg.platform().unwrap().schedule, SchedulePolicy::RoundRobin);
        let cfg =
            ConfigFile::parse("[platform]\nschedule = \"least-loaded-blind\"").unwrap();
        assert_eq!(cfg.platform().unwrap().schedule, SchedulePolicy::LeastLoadedBlind);
        let cfg = ConfigFile::parse("[platform]\nschedule = \"fifo\"").unwrap();
        assert!(cfg.platform().is_err());
    }

    #[test]
    fn parses_migration_section() {
        let cfg = ConfigFile::parse(SAMPLE).unwrap();
        let m = cfg.migration().unwrap();
        assert_eq!(m.policy, DataPolicy::BundleAlways);
        assert_eq!(m.decision, Decision::CostBased);
        assert_eq!(m.attempts, 3);
        assert!(m.local_fallback);
        assert!(!m.admission, "admission control defaults off");
        assert!(m.signing.is_some());
        assert_eq!(cfg.codec().unwrap(), Codec::Deflate);
        let cfg = ConfigFile::parse("[migration]\nadmission = true").unwrap();
        assert!(cfg.migration().unwrap().admission);
    }

    #[test]
    fn empty_config_is_all_defaults() {
        let cfg = ConfigFile::parse("").unwrap();
        let p = cfg.platform().unwrap();
        assert_eq!(p.local_nodes, PlatformConfig::default().local_nodes);
        let m = cfg.migration().unwrap();
        assert_eq!(m.policy, DataPolicy::Mdss);
        assert!(m.signing.is_none());
    }

    #[test]
    fn rejects_malformed() {
        assert!(ConfigFile::parse("[platform\nx = 1").is_err());
        assert!(ConfigFile::parse("[p]\nnot a kv").is_err());
        assert!(ConfigFile::parse("[p]\nx = @@").is_err());
        assert!(ConfigFile::parse("[]\n").is_err());
    }

    #[test]
    fn type_errors_reported() {
        let cfg = ConfigFile::parse("[platform]\nlocal_nodes = \"many\"").unwrap();
        let err = format!("{:#}", cfg.platform().unwrap_err());
        assert!(err.contains("must be a number"), "{err}");
        let cfg = ConfigFile::parse("[migration]\npolicy = \"carrier-pigeon\"").unwrap();
        assert!(cfg.migration().is_err());
    }

    #[test]
    fn comments_and_whitespace_ignored() {
        let cfg = ConfigFile::parse("  [platform]  # x\n local_speed = 2.0 # fast\n").unwrap();
        assert_eq!(cfg.platform().unwrap().local_speed, 2.0);
    }
}
