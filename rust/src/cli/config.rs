//! Platform/manager configuration files (substrate: a TOML subset —
//! no toml crate offline).
//!
//! A deployable coordinator needs its testbed parameters in a file,
//! not in code. `emerald ... --platform emerald.toml` loads one:
//!
//! ```toml
//! # emerald.toml
//! [platform]
//! local_nodes = 10
//! local_speed = 1.0
//! cloud_nodes = 25
//! cloud_speed = 4.0
//! wan_mbits = 200.0
//! wan_latency_ms = 10
//! schedule = "least-loaded"  # least-loaded | round-robin
//!
//! [migration]
//! policy = "mdss"          # mdss | bundle
//! decision = "always"      # always | cost
//! attempts = 1
//! local_fallback = false
//! signing_key = ""         # non-empty enables request signing
//! codec = "raw"            # raw | deflate
//! ```
//!
//! Supported grammar: `[section]` headers, `key = value` with string /
//! number / boolean values, `#` comments, blank lines.

use std::collections::BTreeMap;
use std::time::Duration;

use anyhow::{bail, Context, Result};

use crate::cloud::PlatformConfig;
use crate::mdss::Codec;
use crate::migration::{DataPolicy, Decision, ManagerConfig, SigningKey};
use crate::scheduler::SchedulePolicy;

/// A parsed config file: section -> key -> raw value.
#[derive(Debug, Default, Clone, PartialEq)]
pub struct ConfigFile {
    sections: BTreeMap<String, BTreeMap<String, ConfigValue>>,
}

/// A config value.
#[derive(Debug, Clone, PartialEq)]
pub enum ConfigValue {
    Str(String),
    Num(f64),
    Bool(bool),
}

impl ConfigValue {
    fn kind(&self) -> &'static str {
        match self {
            ConfigValue::Str(_) => "string",
            ConfigValue::Num(_) => "number",
            ConfigValue::Bool(_) => "boolean",
        }
    }
}

impl ConfigFile {
    /// Parse config text.
    pub fn parse(text: &str) -> Result<Self> {
        let mut out = Self::default();
        let mut section = String::new();
        for (lineno, raw) in text.lines().enumerate() {
            let line = raw.split('#').next().unwrap_or("").trim();
            if line.is_empty() {
                continue;
            }
            if let Some(name) = line.strip_prefix('[').and_then(|s| s.strip_suffix(']')) {
                section = name.trim().to_string();
                if section.is_empty() {
                    bail!("line {}: empty section name", lineno + 1);
                }
                out.sections.entry(section.clone()).or_default();
                continue;
            }
            let Some((key, value)) = line.split_once('=') else {
                bail!("line {}: expected `key = value`, got {line:?}", lineno + 1);
            };
            let key = key.trim().to_string();
            let value = Self::parse_value(value.trim())
                .with_context(|| format!("line {}: value for {key}", lineno + 1))?;
            out.sections.entry(section.clone()).or_default().insert(key, value);
        }
        Ok(out)
    }

    fn parse_value(raw: &str) -> Result<ConfigValue> {
        if raw == "true" {
            return Ok(ConfigValue::Bool(true));
        }
        if raw == "false" {
            return Ok(ConfigValue::Bool(false));
        }
        if let Some(s) = raw.strip_prefix('"').and_then(|s| s.strip_suffix('"')) {
            return Ok(ConfigValue::Str(s.to_string()));
        }
        raw.parse::<f64>()
            .map(ConfigValue::Num)
            .map_err(|_| anyhow::anyhow!("cannot parse value {raw:?}"))
    }

    /// Load from a file path.
    pub fn load(path: impl AsRef<std::path::Path>) -> Result<Self> {
        let path = path.as_ref();
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading config {}", path.display()))?;
        Self::parse(&text).with_context(|| format!("parsing {}", path.display()))
    }

    fn get(&self, section: &str, key: &str) -> Option<&ConfigValue> {
        self.sections.get(section)?.get(key)
    }

    fn num(&self, section: &str, key: &str, default: f64) -> Result<f64> {
        match self.get(section, key) {
            None => Ok(default),
            Some(ConfigValue::Num(n)) => Ok(*n),
            Some(v) => bail!("[{section}] {key} must be a number, got {}", v.kind()),
        }
    }

    fn string(&self, section: &str, key: &str, default: &str) -> Result<String> {
        match self.get(section, key) {
            None => Ok(default.to_string()),
            Some(ConfigValue::Str(s)) => Ok(s.clone()),
            Some(v) => bail!("[{section}] {key} must be a string, got {}", v.kind()),
        }
    }

    fn boolean(&self, section: &str, key: &str, default: bool) -> Result<bool> {
        match self.get(section, key) {
            None => Ok(default),
            Some(ConfigValue::Bool(b)) => Ok(*b),
            Some(v) => bail!("[{section}] {key} must be a boolean, got {}", v.kind()),
        }
    }

    /// Build a [`PlatformConfig`] from the `[platform]` section
    /// (missing keys take paper defaults).
    pub fn platform(&self) -> Result<PlatformConfig> {
        let d = PlatformConfig::default();
        let schedule = match self.string("platform", "schedule", "least-loaded")?.as_str() {
            "least-loaded" => SchedulePolicy::LeastLoaded,
            "round-robin" => SchedulePolicy::RoundRobin,
            other => {
                bail!("[platform] schedule must be least-loaded|round-robin, got {other:?}")
            }
        };
        Ok(PlatformConfig {
            local_nodes: self.num("platform", "local_nodes", d.local_nodes as f64)? as usize,
            local_speed: self.num("platform", "local_speed", d.local_speed)?,
            cloud_nodes: self.num("platform", "cloud_nodes", d.cloud_nodes as f64)? as usize,
            cloud_speed: self.num("platform", "cloud_speed", d.cloud_speed)?,
            wan_bandwidth: self.num("platform", "wan_mbits", d.wan_bandwidth * 8.0 / 1e6)?
                * 1e6
                / 8.0,
            wan_latency: Duration::from_secs_f64(
                self.num("platform", "wan_latency_ms", d.wan_latency.as_secs_f64() * 1e3)?
                    / 1e3,
            ),
            schedule,
        })
    }

    /// Build a [`ManagerConfig`] from the `[migration]` section.
    pub fn migration(&self) -> Result<ManagerConfig> {
        let policy = match self.string("migration", "policy", "mdss")?.as_str() {
            "mdss" => DataPolicy::Mdss,
            "bundle" => DataPolicy::BundleAlways,
            other => bail!("[migration] policy must be mdss|bundle, got {other:?}"),
        };
        let mut cfg = ManagerConfig::new(policy);
        cfg.decision = match self.string("migration", "decision", "always")?.as_str() {
            "always" => Decision::Always,
            "cost" => Decision::CostBased,
            other => bail!("[migration] decision must be always|cost, got {other:?}"),
        };
        cfg.attempts = self.num("migration", "attempts", 1.0)? as usize;
        cfg.local_fallback = self.boolean("migration", "local_fallback", false)?;
        let key = self.string("migration", "signing_key", "")?;
        if !key.is_empty() {
            cfg.signing = Some(SigningKey::new(key.into_bytes()));
        }
        Ok(cfg)
    }

    /// MDSS wire codec from the `[migration]` section.
    pub fn codec(&self) -> Result<Codec> {
        match self.string("migration", "codec", "raw")?.as_str() {
            "raw" => Ok(Codec::Raw),
            "deflate" => Ok(Codec::Deflate),
            other => bail!("[migration] codec must be raw|deflate, got {other:?}"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"
        # testbed
        [platform]
        local_nodes = 4
        cloud_speed = 2.5
        wan_mbits = 100.0
        wan_latency_ms = 5

        [migration]
        policy = "bundle"
        decision = "cost"
        attempts = 3
        local_fallback = true
        signing_key = "secret"
        codec = "deflate"
    "#;

    #[test]
    fn parses_platform_with_defaults() {
        let cfg = ConfigFile::parse(SAMPLE).unwrap();
        let p = cfg.platform().unwrap();
        assert_eq!(p.local_nodes, 4);
        assert_eq!(p.cloud_nodes, 25); // default kept
        assert_eq!(p.cloud_speed, 2.5);
        assert_eq!(p.wan_bandwidth, 100.0e6 / 8.0);
        assert_eq!(p.wan_latency, Duration::from_millis(5));
        assert_eq!(p.schedule, SchedulePolicy::LeastLoaded); // default kept
    }

    #[test]
    fn parses_schedule_policy() {
        let cfg =
            ConfigFile::parse("[platform]\nschedule = \"round-robin\"").unwrap();
        assert_eq!(cfg.platform().unwrap().schedule, SchedulePolicy::RoundRobin);
        let cfg = ConfigFile::parse("[platform]\nschedule = \"fifo\"").unwrap();
        assert!(cfg.platform().is_err());
    }

    #[test]
    fn parses_migration_section() {
        let cfg = ConfigFile::parse(SAMPLE).unwrap();
        let m = cfg.migration().unwrap();
        assert_eq!(m.policy, DataPolicy::BundleAlways);
        assert_eq!(m.decision, Decision::CostBased);
        assert_eq!(m.attempts, 3);
        assert!(m.local_fallback);
        assert!(m.signing.is_some());
        assert_eq!(cfg.codec().unwrap(), Codec::Deflate);
    }

    #[test]
    fn empty_config_is_all_defaults() {
        let cfg = ConfigFile::parse("").unwrap();
        let p = cfg.platform().unwrap();
        assert_eq!(p.local_nodes, PlatformConfig::default().local_nodes);
        let m = cfg.migration().unwrap();
        assert_eq!(m.policy, DataPolicy::Mdss);
        assert!(m.signing.is_none());
    }

    #[test]
    fn rejects_malformed() {
        assert!(ConfigFile::parse("[platform\nx = 1").is_err());
        assert!(ConfigFile::parse("[p]\nnot a kv").is_err());
        assert!(ConfigFile::parse("[p]\nx = @@").is_err());
        assert!(ConfigFile::parse("[]\n").is_err());
    }

    #[test]
    fn type_errors_reported() {
        let cfg = ConfigFile::parse("[platform]\nlocal_nodes = \"many\"").unwrap();
        let err = format!("{:#}", cfg.platform().unwrap_err());
        assert!(err.contains("must be a number"), "{err}");
        let cfg = ConfigFile::parse("[migration]\npolicy = \"carrier-pigeon\"").unwrap();
        assert!(cfg.migration().is_err());
    }

    #[test]
    fn comments_and_whitespace_ignored() {
        let cfg = ConfigFile::parse("  [platform]  # x\n local_speed = 2.0 # fast\n").unwrap();
        assert_eq!(cfg.platform().unwrap().local_speed, 2.0);
    }
}
