//! Command-line argument parsing (substrate; clap is not available
//! offline) and configuration files ([`config`]).
//!
//! Supports `--key value`, `--key=value`, boolean `--flag`, and
//! positional arguments, with typed accessors and a generated usage
//! string.

pub mod config;

pub use config::{ConfigFile, EngineConfig};

use std::collections::BTreeMap;

use anyhow::{bail, Context, Result};

/// Parsed command line.
#[derive(Debug, Clone, Default)]
pub struct Args {
    /// Binary name (argv[0]).
    pub program: String,
    /// Positional arguments in order.
    pub positional: Vec<String>,
    /// `--key value` options (last occurrence wins).
    pub options: BTreeMap<String, String>,
    /// Bare `--flag` switches.
    pub flags: Vec<String>,
}

impl Args {
    /// Parse from the process environment. `bool_flags` names the
    /// options that take no value (resolves the `--flag positional`
    /// ambiguity without a full schema language).
    pub fn from_env(bool_flags: &[&str]) -> Self {
        let mut argv = std::env::args();
        let program = argv.next().unwrap_or_default();
        Self::parse(program, argv.collect(), bool_flags)
    }

    /// Parse from an explicit vector (tests).
    pub fn parse(program: String, argv: Vec<String>, bool_flags: &[&str]) -> Self {
        let mut out = Self { program, ..Default::default() };
        let mut i = 0;
        while i < argv.len() {
            let a = &argv[i];
            if let Some(body) = a.strip_prefix("--") {
                if let Some((k, v)) = body.split_once('=') {
                    out.options.insert(k.to_string(), v.to_string());
                } else if bool_flags.contains(&body) {
                    out.flags.push(body.to_string());
                } else if i + 1 < argv.len() && !argv[i + 1].starts_with("--") {
                    out.options.insert(body.to_string(), argv[i + 1].clone());
                    i += 1;
                } else {
                    out.flags.push(body.to_string());
                }
            } else {
                out.positional.push(a.clone());
            }
            i += 1;
        }
        out
    }

    /// Is a boolean flag present?
    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    /// String option with default.
    pub fn opt(&self, name: &str, default: &str) -> String {
        self.options.get(name).cloned().unwrap_or_else(|| default.to_string())
    }

    /// Required string option.
    pub fn require(&self, name: &str) -> Result<String> {
        self.options
            .get(name)
            .cloned()
            .with_context(|| format!("missing required option --{name}"))
    }

    /// Typed option with default.
    pub fn opt_parse<T: std::str::FromStr>(&self, name: &str, default: T) -> Result<T> {
        match self.options.get(name) {
            None => Ok(default),
            Some(raw) => raw
                .parse::<T>()
                .map_err(|_| anyhow::anyhow!("option --{name}={raw} is not a valid value")),
        }
    }

    /// First positional argument (subcommand).
    pub fn subcommand(&self) -> Option<&str> {
        self.positional.first().map(String::as_str)
    }

    /// Reject unknown options/flags (catches typos).
    pub fn check_known(&self, known_opts: &[&str], known_flags: &[&str]) -> Result<()> {
        for k in self.options.keys() {
            if !known_opts.contains(&k.as_str()) {
                bail!("unknown option --{k} (known: {})", known_opts.join(", "));
            }
        }
        for f in &self.flags {
            if !known_flags.contains(&f.as_str()) {
                bail!("unknown flag --{f} (known: {})", known_flags.join(", "));
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn args(v: &[&str]) -> Args {
        Args::parse(
            "prog".into(),
            v.iter().map(|s| s.to_string()).collect(),
            &["offload"],
        )
    }

    #[test]
    fn parse_styles() {
        let a = args(&["run", "--mesh", "small", "--iters=3", "--offload", "x.xml"]);
        assert_eq!(a.subcommand(), Some("run"));
        assert_eq!(a.opt("mesh", "demo"), "small");
        assert_eq!(a.opt_parse::<usize>("iters", 1).unwrap(), 3);
        assert!(a.flag("offload"));
        assert_eq!(a.positional, vec!["run", "x.xml"]);
    }

    #[test]
    fn undeclared_flag_at_end_still_flags() {
        let a = args(&["--verbose"]);
        assert!(a.flag("verbose"));
    }

    #[test]
    fn defaults_and_required() {
        let a = args(&[]);
        assert_eq!(a.opt("mesh", "demo"), "demo");
        assert!(a.require("mesh").is_err());
        assert_eq!(a.opt_parse::<f64>("alpha", 0.5).unwrap(), 0.5);
    }

    #[test]
    fn bad_typed_value() {
        let a = args(&["--iters", "abc"]);
        assert!(a.opt_parse::<usize>("iters", 1).is_err());
    }

    #[test]
    fn unknown_rejected() {
        let a = args(&["--mehs", "small"]);
        assert!(a.check_known(&["mesh"], &[]).is_err());
        let b = args(&["--mesh", "small"]);
        assert!(b.check_known(&["mesh"], &[]).is_ok());
    }
}
