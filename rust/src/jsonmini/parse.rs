//! Recursive-descent JSON parser.

use super::{JsonError, Value};
use std::collections::BTreeMap;

/// Parse a JSON document. Trailing whitespace is allowed; trailing
/// garbage is an error.
pub fn parse(input: &str) -> Result<Value, JsonError> {
    let mut p = Parser { bytes: input.as_bytes(), pos: 0 };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.pos != p.bytes.len() {
        return Err(p.err("trailing characters"));
    }
    Ok(v)
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError::Parse { pos: self.pos, msg: msg.to_string() }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let b = self.peek();
        if b.is_some() {
            self.pos += 1;
        }
        b
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), JsonError> {
        if self.bump() == Some(b) {
            Ok(())
        } else {
            self.pos = self.pos.saturating_sub(1);
            Err(self.err(&format!("expected '{}'", b as char)))
        }
    }

    fn literal(&mut self, word: &str, v: Value) -> Result<Value, JsonError> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{word}'")))
        }
    }

    fn value(&mut self) -> Result<Value, JsonError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Value::Str(self.string()?)),
            Some(b't') => self.literal("true", Value::Bool(true)),
            Some(b'f') => self.literal("false", Value::Bool(false)),
            Some(b'n') => self.literal("null", Value::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            Some(_) => Err(self.err("unexpected character")),
            None => Err(self.err("unexpected end of input")),
        }
    }

    fn object(&mut self) -> Result<Value, JsonError> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Value::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Value::Obj(map)),
                _ => {
                    self.pos = self.pos.saturating_sub(1);
                    return Err(self.err("expected ',' or '}'"));
                }
            }
        }
    }

    fn array(&mut self) -> Result<Value, JsonError> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Value::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Value::Arr(items)),
                _ => {
                    self.pos = self.pos.saturating_sub(1);
                    return Err(self.err("expected ',' or ']'"));
                }
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.bump() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => return Ok(out),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => out.push('"'),
                    Some(b'\\') => out.push('\\'),
                    Some(b'/') => out.push('/'),
                    Some(b'b') => out.push('\u{0008}'),
                    Some(b'f') => out.push('\u{000C}'),
                    Some(b'n') => out.push('\n'),
                    Some(b'r') => out.push('\r'),
                    Some(b't') => out.push('\t'),
                    Some(b'u') => {
                        let code = self.hex4()?;
                        match char::from_u32(code) {
                            Some(c) => out.push(c),
                            // Surrogate halves outside the BMP are
                            // replaced (ASCII payloads in practice).
                            None => out.push('\u{FFFD}'),
                        }
                    }
                    _ => return Err(self.err("bad escape")),
                },
                Some(b) if b < 0x20 => return Err(self.err("control char in string")),
                Some(b) => {
                    // Re-assemble UTF-8 multibyte sequences verbatim.
                    let start = self.pos - 1;
                    let len = utf8_len(b);
                    let end = start + len;
                    if end > self.bytes.len() {
                        return Err(self.err("truncated utf-8"));
                    }
                    match std::str::from_utf8(&self.bytes[start..end]) {
                        Ok(s) => {
                            out.push_str(s);
                            self.pos = end;
                        }
                        Err(_) => return Err(self.err("invalid utf-8")),
                    }
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32, JsonError> {
        let mut code = 0u32;
        for _ in 0..4 {
            let d = self.bump().ok_or_else(|| self.err("truncated \\u escape"))?;
            let v = (d as char).to_digit(16).ok_or_else(|| self.err("bad hex digit"))?;
            code = code * 16 + v;
        }
        Ok(code)
    }

    fn number(&mut self) -> Result<Value, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        text.parse::<f64>()
            .map(Value::Num)
            .map_err(|_| self.err("invalid number"))
    }
}

fn utf8_len(first: u8) -> usize {
    match first {
        b if b < 0x80 => 1,
        b if b >> 5 == 0b110 => 2,
        b if b >> 4 == 0b1110 => 3,
        _ => 4,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rejects_trailing_garbage() {
        assert!(parse("1 2").is_err());
        assert!(parse("{} x").is_err());
    }

    #[test]
    fn rejects_malformed() {
        for bad in ["{", "[1,", "\"abc", "{\"a\"}", "tru", "[,]", "1.2.3"] {
            assert!(parse(bad).is_err(), "should reject {bad:?}");
        }
    }

    #[test]
    fn numbers() {
        assert_eq!(parse("-0.5e3").unwrap().as_f64().unwrap(), -500.0);
        assert_eq!(parse("0").unwrap().as_f64().unwrap(), 0.0);
        assert_eq!(parse("1E+2").unwrap().as_f64().unwrap(), 100.0);
    }

    #[test]
    fn unicode_strings() {
        assert_eq!(parse(r#""Ab""#).unwrap().as_str().unwrap(), "Ab");
        assert_eq!(parse("\"héllo\"").unwrap().as_str().unwrap(), "héllo");
    }

    #[test]
    fn empty_containers() {
        assert_eq!(parse("[]").unwrap(), Value::Arr(vec![]));
        assert_eq!(parse("{}").unwrap(), Value::Obj(Default::default()));
        assert_eq!(parse("[ ]").unwrap(), Value::Arr(vec![]));
    }
}
