//! Minimal JSON codec (substrate).
//!
//! serde is not available in this offline environment, so Emerald ships
//! its own small JSON implementation. It is used for the artifact
//! manifest (`artifacts/manifest.json`), the migration wire protocol,
//! and metrics dumps. Supports the full JSON grammar except `\u`
//! surrogate pairs beyond the BMP (sufficient for our ASCII payloads);
//! numbers round-trip as `f64`.

mod parse;
mod write;

pub use parse::parse;
pub use write::{to_string, to_string_pretty};

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value. Objects use a `BTreeMap` so serialization is
/// deterministic (stable hashing for MDSS versions).
#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    /// JSON `null`.
    Null,
    /// JSON boolean.
    Bool(bool),
    /// JSON number (always `f64`).
    Num(f64),
    /// JSON string.
    Str(String),
    /// JSON array.
    Arr(Vec<Value>),
    /// JSON object (sorted keys, deterministic serialization).
    Obj(BTreeMap<String, Value>),
}

/// Errors produced by the parser or by typed accessors.
#[derive(Debug)]
pub enum JsonError {
    /// Malformed input at a byte position.
    Parse {
        /// Byte offset of the error in the input.
        pos: usize,
        /// What went wrong.
        msg: String,
    },
    /// A typed accessor found a different kind of value.
    Type {
        /// The kind the accessor wanted.
        expected: &'static str,
        /// The kind actually present.
        got: &'static str,
    },
    /// [`Value::get`] on an object without the key.
    MissingKey(String),
}

impl std::fmt::Display for JsonError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            JsonError::Parse { pos, msg } => write!(f, "json parse error at byte {pos}: {msg}"),
            JsonError::Type { expected, got } => {
                write!(f, "json type error: expected {expected}, got {got}")
            }
            JsonError::MissingKey(key) => write!(f, "json missing key: {key}"),
        }
    }
}

impl std::error::Error for JsonError {}

impl Value {
    fn kind(&self) -> &'static str {
        match self {
            Value::Null => "null",
            Value::Bool(_) => "bool",
            Value::Num(_) => "number",
            Value::Str(_) => "string",
            Value::Arr(_) => "array",
            Value::Obj(_) => "object",
        }
    }

    /// Typed accessor: number as f64.
    pub fn as_f64(&self) -> Result<f64, JsonError> {
        match self {
            Value::Num(n) => Ok(*n),
            v => Err(JsonError::Type { expected: "number", got: v.kind() }),
        }
    }

    /// Typed accessor: number as usize (must be a non-negative integer).
    pub fn as_usize(&self) -> Result<usize, JsonError> {
        let n = self.as_f64()?;
        if n < 0.0 || n.fract() != 0.0 {
            return Err(JsonError::Type { expected: "non-negative integer", got: "number" });
        }
        Ok(n as usize)
    }

    /// Typed accessor: i64.
    pub fn as_i64(&self) -> Result<i64, JsonError> {
        let n = self.as_f64()?;
        if n.fract() != 0.0 {
            return Err(JsonError::Type { expected: "integer", got: "number" });
        }
        Ok(n as i64)
    }

    /// Typed accessor: string slice.
    pub fn as_str(&self) -> Result<&str, JsonError> {
        match self {
            Value::Str(s) => Ok(s),
            v => Err(JsonError::Type { expected: "string", got: v.kind() }),
        }
    }

    /// Typed accessor: bool.
    pub fn as_bool(&self) -> Result<bool, JsonError> {
        match self {
            Value::Bool(b) => Ok(*b),
            v => Err(JsonError::Type { expected: "bool", got: v.kind() }),
        }
    }

    /// Typed accessor: array slice.
    pub fn as_arr(&self) -> Result<&[Value], JsonError> {
        match self {
            Value::Arr(a) => Ok(a),
            v => Err(JsonError::Type { expected: "array", got: v.kind() }),
        }
    }

    /// Typed accessor: object map.
    pub fn as_obj(&self) -> Result<&BTreeMap<String, Value>, JsonError> {
        match self {
            Value::Obj(o) => Ok(o),
            v => Err(JsonError::Type { expected: "object", got: v.kind() }),
        }
    }

    /// Object field lookup (error when missing).
    pub fn get(&self, key: &str) -> Result<&Value, JsonError> {
        self.as_obj()?
            .get(key)
            .ok_or_else(|| JsonError::MissingKey(key.to_string()))
    }

    /// Object field lookup returning `None` when absent.
    pub fn get_opt(&self, key: &str) -> Option<&Value> {
        match self {
            Value::Obj(o) => o.get(key),
            _ => None,
        }
    }

    /// Builder: object from pairs.
    pub fn obj(pairs: impl IntoIterator<Item = (&'static str, Value)>) -> Value {
        Value::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    /// Builder: array of values.
    pub fn arr(items: impl IntoIterator<Item = Value>) -> Value {
        Value::Arr(items.into_iter().collect())
    }

    /// Builder: string value.
    pub fn str(s: impl Into<String>) -> Value {
        Value::Str(s.into())
    }

    /// Builder: number value.
    pub fn num(n: impl Into<f64>) -> Value {
        Value::Num(n.into())
    }
}

impl fmt::Display for Value {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&to_string(self))
    }
}

impl From<&str> for Value {
    fn from(s: &str) -> Self {
        Value::Str(s.to_string())
    }
}
impl From<String> for Value {
    fn from(s: String) -> Self {
        Value::Str(s)
    }
}
impl From<f64> for Value {
    fn from(n: f64) -> Self {
        Value::Num(n)
    }
}
impl From<usize> for Value {
    fn from(n: usize) -> Self {
        Value::Num(n as f64)
    }
}
impl From<u64> for Value {
    fn from(n: u64) -> Self {
        Value::Num(n as f64)
    }
}
impl From<bool> for Value {
    fn from(b: bool) -> Self {
        Value::Bool(b)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_simple() {
        let v = Value::obj([
            ("a", Value::num(1.5)),
            ("b", Value::arr([Value::Bool(true), Value::Null])),
            ("c", Value::str("hi\n\"there\"")),
        ]);
        let s = to_string(&v);
        assert_eq!(parse(&s).unwrap(), v);
    }

    #[test]
    fn parse_nested() {
        let v = parse(r#"{"x": [1, 2, {"y": -3.5e2}], "z": null}"#).unwrap();
        assert_eq!(v.get("x").unwrap().as_arr().unwrap()[2].get("y").unwrap().as_f64().unwrap(), -350.0);
        assert_eq!(v.get("z").unwrap(), &Value::Null);
    }

    #[test]
    fn typed_accessor_errors() {
        let v = parse("[1]").unwrap();
        assert!(v.as_obj().is_err());
        assert!(v.as_arr().unwrap()[0].as_str().is_err());
        assert!(matches!(
            parse("{}").unwrap().get("nope"),
            Err(JsonError::MissingKey(_))
        ));
    }

    #[test]
    fn as_usize_rejects_fractions_and_negatives() {
        assert!(parse("1.5").unwrap().as_usize().is_err());
        assert!(parse("-2").unwrap().as_usize().is_err());
        assert_eq!(parse("42").unwrap().as_usize().unwrap(), 42);
    }

    #[test]
    fn display_matches_to_string() {
        let v = parse(r#"{"k": [true, false]}"#).unwrap();
        assert_eq!(format!("{v}"), to_string(&v));
    }
}
