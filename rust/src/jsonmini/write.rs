//! JSON serializer (compact and pretty variants).

use super::Value;

/// Serialize compactly (no whitespace). Deterministic: object keys are
/// already sorted by the `BTreeMap` representation.
pub fn to_string(v: &Value) -> String {
    let mut out = String::new();
    write_value(v, None, 0, &mut out);
    out
}

/// Serialize with 2-space indentation.
pub fn to_string_pretty(v: &Value) -> String {
    let mut out = String::new();
    write_value(v, Some(2), 0, &mut out);
    out
}

fn write_value(v: &Value, indent: Option<usize>, depth: usize, out: &mut String) {
    match v {
        Value::Null => out.push_str("null"),
        Value::Bool(true) => out.push_str("true"),
        Value::Bool(false) => out.push_str("false"),
        Value::Num(n) => write_num(*n, out),
        Value::Str(s) => write_str(s, out),
        Value::Arr(items) => {
            out.push('[');
            for (i, item) in items.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                newline_indent(indent, depth + 1, out);
                write_value(item, indent, depth + 1, out);
            }
            if !items.is_empty() {
                newline_indent(indent, depth, out);
            }
            out.push(']');
        }
        Value::Obj(map) => {
            out.push('{');
            for (i, (k, val)) in map.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                newline_indent(indent, depth + 1, out);
                write_str(k, out);
                out.push(':');
                if indent.is_some() {
                    out.push(' ');
                }
                write_value(val, indent, depth + 1, out);
            }
            if !map.is_empty() {
                newline_indent(indent, depth, out);
            }
            out.push('}');
        }
    }
}

fn newline_indent(indent: Option<usize>, depth: usize, out: &mut String) {
    if let Some(w) = indent {
        out.push('\n');
        for _ in 0..w * depth {
            out.push(' ');
        }
    }
}

fn write_num(n: f64, out: &mut String) {
    if !n.is_finite() {
        // JSON has no Inf/NaN; emit null like most encoders.
        out.push_str("null");
    } else if n.fract() == 0.0 && n.abs() < 9e15 {
        out.push_str(&format!("{}", n as i64));
    } else {
        out.push_str(&format!("{n}"));
    }
}

fn write_str(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

#[cfg(test)]
mod tests {
    use super::super::parse;
    use super::*;

    #[test]
    fn integers_have_no_fraction() {
        assert_eq!(to_string(&Value::Num(3.0)), "3");
        assert_eq!(to_string(&Value::Num(3.5)), "3.5");
        assert_eq!(to_string(&Value::Num(-0.0)), "0");
    }

    #[test]
    fn escapes_roundtrip() {
        let v = Value::Str("a\"b\\c\n\t\u{0001}".to_string());
        assert_eq!(parse(&to_string(&v)).unwrap(), v);
    }

    #[test]
    fn pretty_is_parseable() {
        let v = Value::obj([
            ("arr", Value::arr([Value::num(1.0), Value::num(2.0)])),
            ("obj", Value::obj([("k", Value::Null)])),
        ]);
        assert_eq!(parse(&to_string_pretty(&v)).unwrap(), v);
    }

    #[test]
    fn nonfinite_becomes_null() {
        assert_eq!(to_string(&Value::Num(f64::NAN)), "null");
        assert_eq!(to_string(&Value::Num(f64::INFINITY)), "null");
    }
}
