//! Seeded fault injection for the simulated cloud — the hostile-cloud
//! model (`[faults]` config section / `--fault-seed`).
//!
//! The polite cloud the paper evaluates against never kills a VM; real
//! clouds do, and spot-priced capacity does so *by contract*. This
//! module injects **mid-offload VM preemption** deterministically: a
//! [`FaultPlan`] is a pure function of its seed, the step name, and a
//! per-step attempt counter, so a chaos run is byte-for-byte
//! replayable from its seed alone (`docs/FAULTS.md`).
//!
//! Design constraints, in order:
//!
//! 1. **Determinism.** The decision for attempt *k* of step *s* is
//!    `hash(seed, fnv(s), k) < rate` — it does not depend on wall
//!    time, thread interleaving, or how many *other* steps offloaded
//!    first. Two runs with the same seed and the same per-step attempt
//!    sequence make identical decisions; in sequential mode the whole
//!    trace (including `OffloadPreempted` / `OffloadRetried` events)
//!    is byte-identical across runs, which the repeat-run test in
//!    `tests/failure_injection.rs` pins.
//! 2. **Replayability.** A failing chaos seed from CI
//!    (`EMERALD_FAULT_SEED`) reproduces locally with the same config —
//!    nothing else feeds the plan.
//! 3. **Boundedness.** [`FaultConfig::max_preemptions`] caps the total
//!    number of injected faults so a hostile rate cannot starve a
//!    retrying workflow forever.
//!
//! The migration manager consults [`FaultPlan::preempts`] once per
//! placement attempt (initial lease and each retry-elsewhere
//! relocation), so a step can be preempted repeatedly until its
//! retries exhaust — exactly the worst case the recovery path must
//! survive.

use std::collections::BTreeMap;
use std::sync::{Arc, Mutex};

use anyhow::{bail, Result};

/// Configuration of a [`FaultPlan`] (`[faults]` in the config file).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct FaultConfig {
    /// Seed of the deterministic fault stream. Same seed + same config
    /// ⇒ same faults, always.
    pub seed: u64,
    /// Probability in `[0, 1]` that any given offload placement is
    /// preempted mid-flight (`[faults] preempt_rate`).
    pub preempt_rate: f64,
    /// Cap on the total number of injected preemptions across the
    /// plan's lifetime; `None` = unbounded.
    pub max_preemptions: Option<u64>,
}

impl FaultConfig {
    /// A plan that injects nothing (rate 0.0) — the polite cloud.
    pub fn none() -> Self {
        Self { seed: 0, preempt_rate: 0.0, max_preemptions: None }
    }

    /// The `--fault-seed N` shorthand: a moderately hostile cloud
    /// (every fourth placement dies, unbounded) driven by `seed`.
    pub fn seeded(seed: u64) -> Self {
        Self { seed, preempt_rate: 0.25, max_preemptions: None }
    }

    /// Reject rates outside `[0, 1]` (NaN included).
    pub fn validate(&self) -> Result<()> {
        if !(0.0..=1.0).contains(&self.preempt_rate) {
            bail!(
                "fault config: preempt_rate must be in [0, 1], got {}",
                self.preempt_rate
            );
        }
        Ok(())
    }
}

/// Interior state: per-step attempt counters plus the global fired
/// count, under one lock so the `max_preemptions` check and the
/// counter bump are atomic.
#[derive(Debug, Default)]
struct PlanState {
    attempts: BTreeMap<String, u64>,
    fired: u64,
}

/// A deterministic, seeded preemption schedule (see the module doc).
///
/// Shared `Arc`-style between the CLI, the migration manager, and test
/// harnesses; interior counters make it single-use — build a fresh
/// plan per run to replay a seed.
#[derive(Debug)]
pub struct FaultPlan {
    config: FaultConfig,
    state: Mutex<PlanState>,
}

/// SplitMix64 finalizer — the same mixer `quickprop` seeds its
/// generator with; full avalanche, so consecutive attempt indices give
/// independent-looking decisions.
fn mix(mut z: u64) -> u64 {
    z = z.wrapping_add(0x9e3779b97f4a7c15);
    z = (z ^ (z >> 30)).wrapping_mul(0xbf58476d1ce4e5b9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94d049bb133111eb);
    z ^ (z >> 31)
}

/// FNV-1a over the step name: folds the *identity* of the step into
/// the stream so renaming a step re-rolls its faults but reordering
/// unrelated steps does not.
fn fnv(name: &str) -> u64 {
    let mut h: u64 = 0xcbf29ce484222325;
    for b in name.as_bytes() {
        h ^= u64::from(*b);
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

/// `mix` output mapped onto `[0, 1)`.
fn unit(z: u64) -> f64 {
    (z >> 11) as f64 / (1u64 << 53) as f64
}

impl FaultPlan {
    /// Build a plan from a validated config.
    pub fn new(config: FaultConfig) -> Result<Arc<Self>> {
        config.validate()?;
        Ok(Arc::new(Self { config, state: Mutex::new(PlanState::default()) }))
    }

    /// The `--fault-seed` shorthand plan ([`FaultConfig::seeded`]).
    pub fn seeded(seed: u64) -> Arc<Self> {
        Self::new(FaultConfig::seeded(seed)).expect("seeded() config is valid")
    }

    /// The config the plan was built from.
    pub fn config(&self) -> FaultConfig {
        self.config
    }

    /// Decide whether the *next* placement attempt of `step` is
    /// preempted, advancing the step's attempt counter. Deterministic:
    /// attempt *k* of step *s* always gets the same verdict under the
    /// same seed, no matter what other steps did in between.
    pub fn preempts(&self, step: &str) -> bool {
        if self.config.preempt_rate <= 0.0 {
            return false;
        }
        let mut st = self.state.lock().unwrap();
        let k = st.attempts.entry(step.to_string()).or_insert(0);
        let attempt = *k;
        *k += 1;
        if let Some(max) = self.config.max_preemptions {
            if st.fired >= max {
                return false;
            }
        }
        let z = mix(self.config.seed ^ fnv(step).wrapping_add(attempt.wrapping_mul(0x9e3779b97f4a7c15)));
        let hit = unit(z) < self.config.preempt_rate;
        if hit {
            st.fired += 1;
        }
        hit
    }

    /// Total preemptions injected so far.
    pub fn fired(&self) -> u64 {
        self.state.lock().unwrap().fired
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn same_seed_same_decisions() {
        let decisions = |seed: u64| -> Vec<bool> {
            let plan = FaultPlan::seeded(seed);
            (0..64).map(|i| plan.preempts(&format!("s{}", i % 8))).collect()
        };
        assert_eq!(decisions(7), decisions(7), "a seed fully determines the stream");
        assert_ne!(decisions(7), decisions(8), "different seeds differ");
    }

    #[test]
    fn decisions_are_per_step_independent_of_interleaving() {
        // Run the same per-step attempt sequences in two different
        // global orders: each step must see the same verdicts.
        let a = FaultPlan::seeded(42);
        let b = FaultPlan::seeded(42);
        let mut va = Vec::new();
        for _ in 0..8 {
            va.push(("x", a.preempts("x")));
        }
        for _ in 0..8 {
            va.push(("y", a.preempts("y")));
        }
        let mut vb = Vec::new();
        for _ in 0..8 {
            vb.push(("y", b.preempts("y")));
            vb.push(("x", b.preempts("x")));
        }
        let of = |v: &[(&str, bool)], s: &str| -> Vec<bool> {
            v.iter().filter(|(n, _)| *n == s).map(|(_, h)| *h).collect()
        };
        assert_eq!(of(&va, "x"), of(&vb, "x"));
        assert_eq!(of(&va, "y"), of(&vb, "y"));
    }

    #[test]
    fn rate_bounds_enforced() {
        assert!(FaultPlan::new(FaultConfig { seed: 0, preempt_rate: 1.5, max_preemptions: None })
            .is_err());
        assert!(FaultPlan::new(FaultConfig { seed: 0, preempt_rate: f64::NAN, max_preemptions: None })
            .is_err());
        let never = FaultPlan::new(FaultConfig::none()).unwrap();
        assert!((0..100).all(|_| !never.preempts("s")), "rate 0.0 never fires");
        let always =
            FaultPlan::new(FaultConfig { seed: 1, preempt_rate: 1.0, max_preemptions: None })
                .unwrap();
        assert!((0..100).all(|_| always.preempts("s")), "rate 1.0 always fires");
    }

    #[test]
    fn max_preemptions_caps_the_plan() {
        let plan =
            FaultPlan::new(FaultConfig { seed: 3, preempt_rate: 1.0, max_preemptions: Some(2) })
                .unwrap();
        let hits: usize = (0..10).filter(|_| plan.preempts("s")).count();
        assert_eq!(hits, 2, "the cap bounds total injected faults");
        assert_eq!(plan.fired(), 2);
    }
}
