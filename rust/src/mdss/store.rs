//! One MDSS storage tier: versioned, content-hashed items keyed by URI.

use std::collections::BTreeMap;
use std::sync::Mutex;

use sha2::{Digest, Sha256};

use super::uri::Uri;

/// Monotonic logical version (last-writer-wins ordering).
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct Version(pub u64);

/// A stored data item.
#[derive(Debug, Clone, PartialEq)]
pub struct DataItem {
    /// The item's address.
    pub uri: Uri,
    /// Logical version (last-writer-wins).
    pub version: Version,
    /// SHA-256 of the payload (integrity + cheap equality).
    pub hash: [u8; 32],
    /// The item's bytes.
    pub payload: Vec<u8>,
}

impl DataItem {
    /// Build an item, computing the content hash.
    pub fn new(uri: Uri, payload: Vec<u8>, version: Version) -> Self {
        let hash = Sha256::digest(&payload).into();
        Self { uri, version, hash, payload }
    }

    /// Verify payload integrity against the stored hash.
    pub fn verify(&self) -> bool {
        let h: [u8; 32] = Sha256::digest(&self.payload).into();
        h == self.hash
    }
}

/// A single tier (local computer or cloud).
pub struct Store {
    #[allow(dead_code)]
    name: &'static str,
    items: Mutex<BTreeMap<Uri, DataItem>>,
}

impl Store {
    /// New empty store.
    pub fn new(name: &'static str) -> Self {
        Self { name, items: Mutex::new(BTreeMap::new()) }
    }

    /// Insert a fresh payload with an externally-allocated version.
    pub fn put(&self, uri: &Uri, payload: Vec<u8>, version: Version) {
        let item = DataItem::new(uri.clone(), payload, version);
        self.items.lock().unwrap().insert(uri.clone(), item);
    }

    /// Insert a fully-formed item (replication path).
    pub fn put_item(&self, item: DataItem) {
        self.items.lock().unwrap().insert(item.uri.clone(), item);
    }

    /// Fetch a copy of an item.
    pub fn get(&self, uri: &Uri) -> Option<DataItem> {
        self.items.lock().unwrap().get(uri).cloned()
    }

    /// Drop an item from this tier. Returns whether it was present
    /// (resident-teardown accounting wants the count of real
    /// releases, not of sweep attempts).
    pub fn remove(&self, uri: &Uri) -> bool {
        self.items.lock().unwrap().remove(uri).is_some()
    }

    /// Version only (freshness checks without copying payloads).
    pub fn version(&self, uri: &Uri) -> Option<Version> {
        self.items.lock().unwrap().get(uri).map(|i| i.version)
    }

    /// All URIs on this tier.
    pub fn uris(&self) -> Vec<Uri> {
        self.items.lock().unwrap().keys().cloned().collect()
    }

    /// Number of items.
    pub fn len(&self) -> usize {
        self.items.lock().unwrap().len()
    }

    /// True when the tier holds nothing.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Total payload bytes on this tier.
    pub fn total_bytes(&self) -> u64 {
        self.items
            .lock()
            .unwrap()
            .values()
            .map(|i| i.payload.len() as u64)
            .sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn u(s: &str) -> Uri {
        Uri::parse(s).unwrap()
    }

    #[test]
    fn put_get_version() {
        let s = Store::new("t");
        s.put(&u("mdss://a/b"), vec![1, 2], Version(5));
        let item = s.get(&u("mdss://a/b")).unwrap();
        assert_eq!(item.version, Version(5));
        assert!(item.verify());
        assert_eq!(s.version(&u("mdss://a/b")), Some(Version(5)));
        assert_eq!(s.version(&u("mdss://a/c")), None);
    }

    #[test]
    fn overwrite_replaces() {
        let s = Store::new("t");
        s.put(&u("mdss://a/b"), vec![1], Version(1));
        s.put(&u("mdss://a/b"), vec![2, 3], Version(2));
        assert_eq!(s.get(&u("mdss://a/b")).unwrap().payload, vec![2, 3]);
        assert_eq!(s.len(), 1);
        assert_eq!(s.total_bytes(), 2);
    }

    #[test]
    fn hash_detects_corruption() {
        let mut item = DataItem::new(u("mdss://a/b"), vec![9, 9], Version(1));
        assert!(item.verify());
        item.payload[0] = 0;
        assert!(!item.verify());
    }

    #[test]
    fn versions_order() {
        assert!(Version(3) > Version(2));
    }
}
