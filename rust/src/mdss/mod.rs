//! Multi-level Data Storage Service (paper §3.4).
//!
//! MDSS separates a remotable step's *application data* (large tensors,
//! images…) from its *task code* (small). Data lives in versioned,
//! URI-addressed stores — one per tier (local computer / cloud) — and
//! steps reference it by URI. Before offloading a step, the migration
//! manager asks MDSS whether the cloud already has the latest version
//! of the step's data: if yes, only task code crosses the wire; if not,
//! MDSS synchronizes first (paper Fig 10).
//!
//! Semantics implemented exactly as specified in §3.4:
//! * new data is saved on the generating tier first (always accessible,
//!   offline-capable); it reaches the other tier on synchronization;
//! * `synchronize` compares versions and writes the latest updates "as
//!   necessary to the local copy and the cloud";
//! * conflict policy is **last-written version wins** (logical clock).

pub mod codec;
pub mod store;
pub mod uri;

pub use codec::Codec;
pub use store::{DataItem, Store, Version};
pub use uri::Uri;

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

use anyhow::{bail, Context, Result};

use crate::cloud::{NodeKind, SimNetwork};

/// Freshness of the cloud copy relative to the local one — the
/// decision input of paper Fig 10.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CloudState {
    /// Cloud already has the latest version: offload task code only.
    Fresh,
    /// Cloud has an older version: synchronize before offloading.
    Stale,
    /// Cloud has no copy at all: full upload needed.
    Missing,
    /// Neither side has the item.
    Unknown,
}

/// Synchronization statistics (per call and cumulative).
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct SyncStats {
    /// Items pushed local → cloud.
    pub uploads: u64,
    /// Items pulled cloud → local.
    pub downloads: u64,
    /// Payload bytes pushed local → cloud.
    pub bytes_up: u64,
    /// Payload bytes pulled cloud → local.
    pub bytes_down: u64,
    /// Simulated time spent on the wire.
    pub sim_time: Duration,
}

impl SyncStats {
    fn add(&mut self, other: &SyncStats) {
        self.uploads += other.uploads;
        self.downloads += other.downloads;
        self.bytes_up += other.bytes_up;
        self.bytes_down += other.bytes_down;
        self.sim_time += other.sim_time;
    }
}

/// The two-tier storage service.
pub struct Mdss {
    local: Store,
    cloud: Store,
    net: Arc<SimNetwork>,
    codec: Codec,
    clock: AtomicU64,
    stats: Mutex<SyncStats>,
    /// Payloads strictly below this many bytes skip the codec and
    /// cross the wire raw: on sub-threshold payloads the vendored LZ77
    /// pass costs more than the bytes it saves (`runtime_micro`
    /// measures the crossover), and tiny inputs often *expand* under
    /// compression. Zero disables the bypass.
    compress_min: AtomicU64,
}

impl Mdss {
    /// New MDSS over a simulated WAN (raw transfers, as in the paper).
    pub fn new(net: Arc<SimNetwork>) -> Arc<Self> {
        Self::with_codec(net, Codec::Raw)
    }

    /// MDSS with a wire codec (future-work §6 placement strategy:
    /// compressed transfers).
    pub fn with_codec(net: Arc<SimNetwork>, codec: Codec) -> Arc<Self> {
        Arc::new(Self {
            local: Store::new("local"),
            cloud: Store::new("cloud"),
            net,
            codec,
            clock: AtomicU64::new(1),
            stats: Mutex::new(SyncStats::default()),
            compress_min: AtomicU64::new(0),
        })
    }

    /// Set the small-payload compression bypass threshold (bytes):
    /// payloads strictly smaller cross the wire uncompressed
    /// (`[migration] compress_min`). Zero disables the bypass.
    pub fn set_compress_min(&self, bytes: u64) {
        self.compress_min.store(bytes, Ordering::Relaxed);
    }

    /// Meter one payload crossing the WAN under the active codec.
    /// Sub-threshold payloads (see [`Self::set_compress_min`]) are
    /// metered at their raw length — the compression pass is skipped
    /// entirely on both ends.
    fn wire_transfer(&self, payload: &[u8]) -> Result<(u64, Duration)> {
        let min = self.compress_min.load(Ordering::Relaxed);
        let wire = if min > 0 && (payload.len() as u64) < min {
            payload.len() as u64
        } else {
            self.codec.wire_len(payload)?
        };
        Ok((wire, self.net.transfer(wire)))
    }

    fn tick(&self) -> Version {
        Version(self.clock.fetch_add(1, Ordering::Relaxed))
    }

    fn store(&self, side: NodeKind) -> &Store {
        match side {
            NodeKind::Local => &self.local,
            NodeKind::Cloud => &self.cloud,
        }
    }

    /// Save data on one tier (no network: paper — "MDSS first saves the
    /// data on local computer, so data is always accessible").
    pub fn put(&self, side: NodeKind, uri: &Uri, payload: Vec<u8>) -> Version {
        let v = self.tick();
        self.store(side).put(uri, payload, v);
        v
    }

    /// Read from one tier only (no network). `None` when absent.
    pub fn peek(&self, side: NodeKind, uri: &Uri) -> Option<DataItem> {
        self.store(side).get(uri)
    }

    /// Copy an item verbatim (same version) from one tier to the
    /// other, without metering. Used by the no-MDSS bundling baseline,
    /// which moves the bytes as part of the request payload instead.
    pub fn replicate(&self, from: NodeKind, to: NodeKind, uri: &Uri) -> Result<()> {
        let item = self
            .store(from)
            .get(uri)
            .with_context(|| format!("replicate: {uri} not on {from} tier"))?;
        self.store(to).put_item(item);
        Ok(())
    }

    /// Freshness of the cloud copy for one URI (Fig 10 decision).
    pub fn cloud_state(&self, uri: &Uri) -> CloudState {
        match (self.local.version(uri), self.cloud.version(uri)) {
            (None, None) => CloudState::Unknown,
            (None, Some(_)) => CloudState::Fresh, // cloud-only data
            (Some(_), None) => CloudState::Missing,
            (Some(l), Some(c)) if c >= l => CloudState::Fresh,
            _ => CloudState::Stale,
        }
    }

    /// Read with on-demand pull: if this tier's copy is missing or
    /// older than the other tier's, the newer copy is transferred
    /// (metered) and cached locally first. Returns the payload and the
    /// simulated transfer time (zero on cache hit).
    pub fn get(&self, side: NodeKind, uri: &Uri) -> Result<(DataItem, Duration)> {
        let other = match side {
            NodeKind::Local => NodeKind::Cloud,
            NodeKind::Cloud => NodeKind::Local,
        };
        let mine = self.store(side).get(uri);
        let theirs = self.store(other).get(uri);
        match (mine, theirs) {
            (Some(m), None) => Ok((m, Duration::ZERO)),
            (Some(m), Some(t)) if m.version >= t.version => Ok((m, Duration::ZERO)),
            (_, Some(t)) => {
                let (wire, d) = self.wire_transfer(&t.payload)?;
                self.store(side).put_item(t.clone());
                let mut s = self.stats.lock().unwrap();
                match side {
                    NodeKind::Local => {
                        s.downloads += 1;
                        s.bytes_down += wire;
                    }
                    NodeKind::Cloud => {
                        s.uploads += 1;
                        s.bytes_up += wire;
                    }
                }
                s.sim_time += d;
                Ok((t, d))
            }
            (None, None) => bail!("MDSS: no data for {uri}"),
        }
    }

    /// Bidirectional reconciliation of one URI (paper: "reads the
    /// latest version of the data available in the cloud and compares
    /// it to the local copy … writes the latest updates as necessary").
    /// Last-written version wins. Returns per-call stats.
    pub fn synchronize(&self, uri: &Uri) -> Result<SyncStats> {
        let mut s = SyncStats::default();
        let l = self.local.get(uri);
        let c = self.cloud.get(uri);
        match (l, c) {
            (None, None) => bail!("MDSS: cannot synchronize unknown {uri}"),
            (Some(li), None) => {
                let (wire, d) = self.wire_transfer(&li.payload)?;
                s.sim_time += d;
                s.uploads += 1;
                s.bytes_up += wire;
                self.cloud.put_item(li);
            }
            (None, Some(ci)) => {
                let (wire, d) = self.wire_transfer(&ci.payload)?;
                s.sim_time += d;
                s.downloads += 1;
                s.bytes_down += wire;
                self.local.put_item(ci);
            }
            (Some(li), Some(ci)) => {
                if li.version > ci.version {
                    let (wire, d) = self.wire_transfer(&li.payload)?;
                    s.sim_time += d;
                    s.uploads += 1;
                    s.bytes_up += wire;
                    self.cloud.put_item(li);
                } else if ci.version > li.version {
                    let (wire, d) = self.wire_transfer(&ci.payload)?;
                    s.sim_time += d;
                    s.downloads += 1;
                    s.bytes_down += wire;
                    self.local.put_item(ci);
                }
                // equal versions: nothing to move
            }
        }
        self.stats.lock().unwrap().add(&s);
        Ok(s)
    }

    /// Synchronize every URI known to either tier.
    pub fn synchronize_all(&self) -> Result<SyncStats> {
        let mut uris = self.local.uris();
        for u in self.cloud.uris() {
            if !uris.contains(&u) {
                uris.push(u);
            }
        }
        let mut total = SyncStats::default();
        for uri in uris {
            total.add(&self.synchronize(&uri)?);
        }
        Ok(total)
    }

    /// Drop one URI from one tier (no network). Returns whether the
    /// tier held it.
    pub fn remove(&self, side: NodeKind, uri: &Uri) -> bool {
        self.store(side).remove(uri)
    }

    /// Drop every URI under `namespace` from **both** tiers and return
    /// how many items were released. Run teardown sweeps the
    /// `resident` namespace through this so no published intermediate
    /// — including stray local copies cached by fetch-on-miss —
    /// outlives its run.
    pub fn sweep_namespace(&self, namespace: &str) -> usize {
        let mut released = 0;
        for store in [&self.local, &self.cloud] {
            for uri in store.uris() {
                if uri.namespace() == namespace && store.remove(&uri) {
                    released += 1;
                }
            }
        }
        released
    }

    /// Drop every `resident`-namespace URI belonging to one run from
    /// **both** tiers and return how many items were released. Run
    /// teardown in a shared process must sweep only its own residents:
    /// service-mode runs publish under
    /// `mdss://resident/<run>-n<node>-<seq>/<var>`, so the sweep
    /// matches on the `<run>-n` path prefix. An empty `run` tag is the
    /// solo identity and sweeps the whole `resident` namespace —
    /// exactly the historical [`Self::sweep_namespace`] behaviour.
    ///
    /// ```
    /// use std::sync::Arc;
    /// use std::time::Duration;
    /// use emerald::cloud::{NodeKind, SimNetwork};
    /// use emerald::mdss::{Mdss, Uri};
    ///
    /// let m = Mdss::new(Arc::new(SimNetwork::new(1e6, Duration::from_millis(1))));
    /// let a = Uri::parse("mdss://resident/r1-n0-0/x")?;
    /// let b = Uri::parse("mdss://resident/r2-n0-0/x")?;
    /// m.put(NodeKind::Cloud, &a, vec![1]);
    /// m.put(NodeKind::Cloud, &b, vec![2]);
    /// assert_eq!(m.sweep_resident_run("r1"), 1); // run 2's item survives
    /// assert_eq!(m.count(NodeKind::Cloud), 1);
    /// assert_eq!(m.sweep_resident_run(""), 1);   // solo sweep takes the rest
    /// # Ok::<(), anyhow::Error>(())
    /// ```
    pub fn sweep_resident_run(&self, run: &str) -> usize {
        if run.is_empty() {
            return self.sweep_namespace("resident");
        }
        let prefix = format!("mdss://resident/{run}-n");
        let mut released = 0;
        for store in [&self.local, &self.cloud] {
            for uri in store.uris() {
                if uri.as_str().starts_with(&prefix) && store.remove(&uri) {
                    released += 1;
                }
            }
        }
        released
    }

    /// Cumulative sync statistics.
    pub fn stats(&self) -> SyncStats {
        *self.stats.lock().unwrap()
    }

    /// Number of items on a tier.
    pub fn count(&self, side: NodeKind) -> usize {
        self.store(side).len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mdss() -> Arc<Mdss> {
        Mdss::new(Arc::new(SimNetwork::new(1e6, Duration::from_millis(1))))
    }

    fn u(s: &str) -> Uri {
        Uri::parse(s).unwrap()
    }

    #[test]
    fn local_put_then_cloud_get_pulls() {
        let m = mdss();
        let uri = u("mdss://at/model");
        m.put(NodeKind::Local, &uri, vec![1, 2, 3]);
        assert_eq!(m.cloud_state(&uri), CloudState::Missing);
        let (item, d) = m.get(NodeKind::Cloud, &uri).unwrap();
        assert_eq!(item.payload, vec![1, 2, 3]);
        assert!(d > Duration::ZERO);
        // Second read is a cache hit.
        let (_, d2) = m.get(NodeKind::Cloud, &uri).unwrap();
        assert_eq!(d2, Duration::ZERO);
        assert_eq!(m.cloud_state(&uri), CloudState::Fresh);
    }

    #[test]
    fn last_writer_wins() {
        let m = mdss();
        let uri = u("mdss://x/y");
        m.put(NodeKind::Local, &uri, vec![1]);
        m.put(NodeKind::Cloud, &uri, vec![2]); // later write
        m.synchronize(&uri).unwrap();
        let (l, _) = m.get(NodeKind::Local, &uri).unwrap();
        let (c, _) = m.get(NodeKind::Cloud, &uri).unwrap();
        assert_eq!(l.payload, vec![2]);
        assert_eq!(c.payload, vec![2]);
    }

    #[test]
    fn synchronize_is_idempotent() {
        let m = mdss();
        let uri = u("mdss://x/y");
        m.put(NodeKind::Local, &uri, vec![7; 100]);
        let s1 = m.synchronize(&uri).unwrap();
        assert_eq!(s1.uploads, 1);
        let s2 = m.synchronize(&uri).unwrap();
        assert_eq!(s2, SyncStats::default()); // nothing moves
    }

    #[test]
    fn stale_cloud_detected() {
        let m = mdss();
        let uri = u("mdss://x/y");
        m.put(NodeKind::Local, &uri, vec![1]);
        m.synchronize(&uri).unwrap();
        assert_eq!(m.cloud_state(&uri), CloudState::Fresh);
        m.put(NodeKind::Local, &uri, vec![2]); // local update
        assert_eq!(m.cloud_state(&uri), CloudState::Stale);
    }

    #[test]
    fn unknown_uri_errors() {
        let m = mdss();
        assert!(m.get(NodeKind::Local, &u("mdss://nope/x")).is_err());
        assert!(m.synchronize(&u("mdss://nope/x")).is_err());
        assert_eq!(m.cloud_state(&u("mdss://nope/x")), CloudState::Unknown);
    }

    #[test]
    fn compressed_codec_meters_fewer_bytes() {
        let net = Arc::new(SimNetwork::new(1e6, Duration::ZERO));
        let m = Mdss::with_codec(net.clone(), Codec::Deflate);
        let uri = u("mdss://x/field");
        // Highly compressible payload (constant field).
        m.put(NodeKind::Local, &uri, vec![0u8; 100_000]);
        let s = m.synchronize(&uri).unwrap();
        assert!(s.bytes_up < 5_000, "compressed bytes: {}", s.bytes_up);
        // Content is intact on the other tier regardless of codec.
        let (item, _) = m.get(NodeKind::Cloud, &uri).unwrap();
        assert_eq!(item.payload.len(), 100_000);
        assert!(item.verify());
    }

    #[test]
    fn sweep_namespace_clears_both_tiers_and_counts() {
        let m = mdss();
        m.put(NodeKind::Cloud, &u("mdss://resident/n0-1/s1"), vec![1]);
        m.put(NodeKind::Cloud, &u("mdss://resident/n0-2/s2"), vec![2]);
        m.put(NodeKind::Local, &u("mdss://resident/n0-1/s1"), vec![1]);
        m.put(NodeKind::Local, &u("mdss://at/model"), vec![9]);
        assert_eq!(m.sweep_namespace("resident"), 3);
        assert_eq!(m.count(NodeKind::Cloud), 0);
        assert_eq!(m.count(NodeKind::Local), 1, "other namespaces survive the sweep");
        assert_eq!(m.sweep_namespace("resident"), 0, "idempotent once clean");
        assert!(m.remove(NodeKind::Local, &u("mdss://at/model")));
        assert!(!m.remove(NodeKind::Local, &u("mdss://at/model")));
    }

    #[test]
    fn small_payloads_bypass_the_codec() {
        let net = Arc::new(SimNetwork::new(1e6, Duration::ZERO));
        let m = Mdss::with_codec(net, Codec::Deflate);
        m.set_compress_min(4096);
        // Sub-threshold: metered at raw length (16 B), not the codec's
        // framed/compressed length.
        let uri = u("mdss://x/tiny");
        m.put(NodeKind::Local, &uri, vec![7u8; 16]);
        let s = m.synchronize(&uri).unwrap();
        assert_eq!(s.bytes_up, 16, "tiny payload crosses raw");
        // At-threshold payloads still compress (constant field).
        let big = u("mdss://x/big");
        m.put(NodeKind::Local, &big, vec![0u8; 4096]);
        let s2 = m.synchronize(&big).unwrap();
        assert!(s2.bytes_up < 4096, "compressed bytes: {}", s2.bytes_up);
    }

    #[test]
    fn synchronize_all_covers_both_tiers() {
        let m = mdss();
        m.put(NodeKind::Local, &u("mdss://a/1"), vec![1]);
        m.put(NodeKind::Cloud, &u("mdss://b/2"), vec![2]);
        let s = m.synchronize_all().unwrap();
        assert_eq!(s.uploads, 1);
        assert_eq!(s.downloads, 1);
        assert_eq!(m.count(NodeKind::Local), 2);
        assert_eq!(m.count(NodeKind::Cloud), 2);
    }
}
