//! MDSS URIs: `mdss://<namespace>/<path...>`.
//!
//! Remotable steps reference application data by URI (paper §3.4);
//! workflow variables carry these as [`crate::expr::Value::Uri`].

use anyhow::{bail, Result};

/// A validated MDSS URI.
#[derive(Debug, Clone, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct Uri {
    raw: String,
}

impl Uri {
    /// Parse and validate.
    pub fn parse(s: &str) -> Result<Self> {
        let Some(rest) = s.strip_prefix("mdss://") else {
            bail!("MDSS URI must start with mdss:// — got {s:?}");
        };
        let mut segs = rest.split('/');
        let ns = segs.next().unwrap_or("");
        if ns.is_empty() {
            bail!("MDSS URI needs a namespace: mdss://<ns>/<path> — got {s:?}");
        }
        let mut any_path = false;
        for seg in segs {
            any_path = true;
            if seg.is_empty() {
                bail!("MDSS URI has an empty path segment: {s:?}");
            }
            if !seg
                .chars()
                .all(|c| c.is_ascii_alphanumeric() || matches!(c, '-' | '_' | '.'))
            {
                bail!("MDSS URI segment {seg:?} has invalid characters");
            }
        }
        if !any_path {
            bail!("MDSS URI needs a path: mdss://<ns>/<path> — got {s:?}");
        }
        Ok(Self { raw: s.to_string() })
    }

    /// Build from parts.
    pub fn new(ns: &str, path: &str) -> Result<Self> {
        Self::parse(&format!("mdss://{ns}/{path}"))
    }

    /// Full string form.
    pub fn as_str(&self) -> &str {
        &self.raw
    }

    /// Namespace (first segment).
    pub fn namespace(&self) -> &str {
        self.raw["mdss://".len()..].split('/').next().unwrap()
    }
}

impl std::fmt::Display for Uri {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(&self.raw)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn valid_uris() {
        let u = Uri::parse("mdss://at/model.c").unwrap();
        assert_eq!(u.namespace(), "at");
        assert_eq!(u.as_str(), "mdss://at/model.c");
        assert!(Uri::parse("mdss://ns/a/b/c-1_2").is_ok());
        assert_eq!(Uri::new("x", "y").unwrap().as_str(), "mdss://x/y");
    }

    #[test]
    fn invalid_uris() {
        for bad in [
            "http://x/y",
            "mdss://",
            "mdss://ns",
            "mdss://ns/",
            "mdss://ns//y",
            "mdss://ns/sp ace",
        ] {
            assert!(Uri::parse(bad).is_err(), "should reject {bad:?}");
        }
    }
}
