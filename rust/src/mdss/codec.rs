//! Wire codecs for MDSS transfers — the paper's future-work §6
//! ("more sophisticated data placement strategies between cloud and
//! local computer to further reduce the data transfer overhead"),
//! implemented as a first-class placement strategy: payloads are
//! compressed before they cross the simulated WAN, so the byte ledger
//! and simulated transfer times reflect the compressed size.
//!
//! Scientific payloads compress well: smooth velocity models and
//! band-limited seismograms are highly redundant in their f32 bit
//! patterns. The E8 ablation bench quantifies the saving.

use std::io::{Read, Write};

use anyhow::{Context, Result};

/// How payloads are encoded on the wire.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Codec {
    /// Raw bytes (the paper's baseline MDSS).
    Raw,
    /// DEFLATE (flate2) compression before transfer.
    Deflate,
}

impl Codec {
    /// Encode a payload for the wire.
    pub fn encode(&self, payload: &[u8]) -> Result<Vec<u8>> {
        match self {
            Codec::Raw => Ok(payload.to_vec()),
            Codec::Deflate => {
                let mut enc = flate2::write::DeflateEncoder::new(
                    Vec::new(),
                    flate2::Compression::fast(),
                );
                enc.write_all(payload).context("compressing payload")?;
                Ok(enc.finish().context("finishing compression")?)
            }
        }
    }

    /// Decode wire bytes back to the payload.
    pub fn decode(&self, wire: &[u8]) -> Result<Vec<u8>> {
        match self {
            Codec::Raw => Ok(wire.to_vec()),
            Codec::Deflate => {
                let mut dec = flate2::read::DeflateDecoder::new(wire);
                let mut out = Vec::new();
                dec.read_to_end(&mut out).context("decompressing payload")?;
                Ok(out)
            }
        }
    }

    /// Bytes a payload occupies on the wire (what the ledger meters).
    pub fn wire_len(&self, payload: &[u8]) -> Result<u64> {
        Ok(self.encode(payload)?.len() as u64)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn raw_is_identity() {
        let data = vec![1u8, 2, 3];
        assert_eq!(Codec::Raw.encode(&data).unwrap(), data);
        assert_eq!(Codec::Raw.wire_len(&data).unwrap(), 3);
    }

    #[test]
    fn deflate_roundtrip() {
        let data: Vec<u8> = (0..10_000).map(|i| (i % 7) as u8).collect();
        let wire = Codec::Deflate.encode(&data).unwrap();
        assert!(wire.len() < data.len() / 4, "repetitive data must shrink");
        assert_eq!(Codec::Deflate.decode(&wire).unwrap(), data);
    }

    #[test]
    fn deflate_on_smooth_f32_fields() {
        // A smooth velocity-model-like field compresses meaningfully.
        let field: Vec<u8> = (0..50_000u32)
            .flat_map(|i| (2.0f32 + 0.001 * (i as f32).sin()).to_le_bytes())
            .collect();
        let wire_len = Codec::Deflate.wire_len(&field).unwrap();
        assert!(
            (wire_len as usize) < field.len(),
            "expected compression, got {wire_len} >= {}",
            field.len()
        );
    }

    #[test]
    fn decode_rejects_garbage() {
        assert!(Codec::Deflate.decode(&[0xFF, 0x00, 0xAB]).is_err());
    }
}
