"""L1: Pallas kernels for Adjoint Tomography's compute hot-spots.

* ``wave``      — 3-D acoustic leap-frog stencil (forward + adjoint
                  propagation; >90% of AT's FLOPs)
* ``correlate`` — zero-lag imaging condition (Frechet accumulator),
                  slab-tiled via BlockSpec
* ``smooth``    — separable 3-point gradient smoothing
* ``ref``       — pure-jnp oracles; pytest asserts allclose agreement

All kernels lower with ``interpret=True`` (CPU PJRT cannot execute
Mosaic custom-calls); DESIGN.md §Hardware-Adaptation documents the
TPU mapping (VMEM-resident blocks, VPU-bound stencil).
"""
