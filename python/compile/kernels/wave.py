"""L1 Pallas kernel: one leap-frog step of the 3-D acoustic wave equation.

This is the compute hot-spot of Adjoint Tomography (paper §4): the same
kernel drives both the forward simulation (AT step 1) and the adjoint
simulation inside the Frechet-kernel computation (AT step 3).

Hardware adaptation (DESIGN.md §Hardware-Adaptation): the paper's AT ran
on Fermi GPUs with CUDA threadblocks tiling the mesh. On TPU the mesh is
kept VMEM-resident as a single block (both paper meshes fit: the large
208x44x46 f32 field is ~1.7 MB, x4 operands ~7 MB < 16 MB VMEM) and the
4th-order stencil is expressed as whole-block shifted adds — VPU vector
ops, not MXU matmuls; the kernel is bandwidth-bound (arithmetic
intensity ~0.5 flop/byte). ``interpret=True`` everywhere: the CPU PJRT
plugin cannot execute Mosaic custom-calls, so the kernel lowers to plain
HLO for the Rust runtime while preserving the block structure.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref


def _wave_step_kernel(u_ref, um_ref, c2_ref, src_ref, out_ref):
    """Pallas kernel body: whole-domain block, 4th-order stencil.

    The stencil is computed on the interior (2-cell halo) with shifted
    block slices; the boundary shell keeps the Dirichlet zero of the
    Laplacian (only ``2u - u_prev + src`` survives there).
    """
    u = u_ref[...]
    um = um_ref[...]
    c2 = c2_ref[...]
    src = src_ref[...]

    lap_int = (
        3.0 * ref.C0 * u[2:-2, 2:-2, 2:-2]
        + ref.C1 * (u[1:-3, 2:-2, 2:-2] + u[3:-1, 2:-2, 2:-2])
        + ref.C2 * (u[:-4, 2:-2, 2:-2] + u[4:, 2:-2, 2:-2])
        + ref.C1 * (u[2:-2, 1:-3, 2:-2] + u[2:-2, 3:-1, 2:-2])
        + ref.C2 * (u[2:-2, :-4, 2:-2] + u[2:-2, 4:, 2:-2])
        + ref.C1 * (u[2:-2, 2:-2, 1:-3] + u[2:-2, 2:-2, 3:-1])
        + ref.C2 * (u[2:-2, 2:-2, :-4] + u[2:-2, 2:-2, 4:])
    )
    lap = jnp.zeros_like(u).at[2:-2, 2:-2, 2:-2].set(lap_int)
    out_ref[...] = 2.0 * u - um + c2 * lap + src


@functools.partial(jax.jit, static_argnames=())
def wave_step(u, u_prev, c2dt2, src):
    """One acoustic leap-frog time step (Pallas, whole-domain block).

    Semantically identical to :func:`ref.wave_step`; pytest enforces
    allclose agreement across shapes and dtypes.
    """
    return pl.pallas_call(
        _wave_step_kernel,
        out_shape=jax.ShapeDtypeStruct(u.shape, u.dtype),
        interpret=True,
    )(u, u_prev, c2dt2, src)
