"""L1 Pallas kernel: separable 3-point Gaussian smoothing.

Regularizes the Frechet kernel before the model update (AT step 4).
Weights ``[1/4, 1/2, 1/4]`` along each axis, edge-replicated boundary.
Whole-domain block (the smoothed gradient is the same size as the
velocity model, VMEM-resident for the paper's meshes); axes are fused in
one kernel body so the intermediate passes never round-trip to HBM.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _axis_smooth(g, axis):
    n = g.shape[axis]
    idx = jnp.arange(n)
    lo = jnp.take(g, jnp.maximum(idx - 1, 0), axis=axis)
    hi = jnp.take(g, jnp.minimum(idx + 1, n - 1), axis=axis)
    return 0.25 * lo + 0.5 * g + 0.25 * hi


def _smooth_kernel(g_ref, out_ref):
    g = g_ref[...]
    for axis in range(3):
        g = _axis_smooth(g, axis)
    out_ref[...] = g


def smooth3(g):
    """3-D separable smoothing; semantically identical to
    :func:`ref.smooth3`."""
    return pl.pallas_call(
        _smooth_kernel,
        out_shape=jax.ShapeDtypeStruct(g.shape, g.dtype),
        interpret=True,
    )(g)
