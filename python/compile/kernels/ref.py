"""Pure-jnp reference oracles for the Pallas kernels.

Every kernel in this package has an oracle here with the exact same
signature and semantics. pytest (``python/tests/``) asserts
``assert_allclose(kernel(...), ref(...))`` across shapes/dtypes via
hypothesis — this is the build-time correctness gate for Layer 1.
"""

import jax.numpy as jnp

# 4th-order central-difference coefficients for the second derivative.
# f'' ~ (-1/12 f[-2] + 4/3 f[-1] - 5/2 f[0] + 4/3 f[+1] - 1/12 f[+2]) / dx^2
C0 = -5.0 / 2.0
C1 = 4.0 / 3.0
C2 = -1.0 / 12.0


def laplacian4(u):
    """4th-order 3-D Laplacian with zero-Dirichlet boundary.

    The returned array is zero on the 2-cell boundary shell; interior
    cells hold the sum of the three axial second derivatives (unit dx —
    grid spacing is folded into ``c2dt2`` by the caller).
    """
    lap = jnp.zeros_like(u)
    interior = (
        3.0 * C0 * u[2:-2, 2:-2, 2:-2]
        + C1 * (u[1:-3, 2:-2, 2:-2] + u[3:-1, 2:-2, 2:-2])
        + C2 * (u[:-4, 2:-2, 2:-2] + u[4:, 2:-2, 2:-2])
        + C1 * (u[2:-2, 1:-3, 2:-2] + u[2:-2, 3:-1, 2:-2])
        + C2 * (u[2:-2, :-4, 2:-2] + u[2:-2, 4:, 2:-2])
        + C1 * (u[2:-2, 2:-2, 1:-3] + u[2:-2, 2:-2, 3:-1])
        + C2 * (u[2:-2, 2:-2, :-4] + u[2:-2, 2:-2, 4:])
    )
    return lap.at[2:-2, 2:-2, 2:-2].set(interior)


def wave_step(u, u_prev, c2dt2, src):
    """One leap-frog step of the 3-D acoustic wave equation.

    ``u_next = 2 u - u_prev + c2dt2 * lap(u) + src``

    ``c2dt2`` is the per-cell ``(c * dt / dx)**2`` field; ``src`` is the
    per-cell source injection for this step (all-zero except at the
    source / adjoint-source cells).
    """
    return 2.0 * u - u_prev + c2dt2 * laplacian4(u) + src


def imaging_step(k_acc, u_fwd, u_adj):
    """Zero-lag cross-correlation imaging condition (the Frechet-kernel
    accumulator): ``K += u_fwd * u_adj``, elementwise."""
    return k_acc + u_fwd * u_adj


def smooth3(g):
    """Separable 3-point ``[1/4, 1/2, 1/4]`` smoothing along each axis
    with edge-replicated boundaries (applied axis 0, then 1, then 2)."""
    for axis in range(3):
        idx = jnp.arange(g.shape[axis])
        lo = jnp.take(g, jnp.maximum(idx - 1, 0), axis=axis)
        hi = jnp.take(g, jnp.minimum(idx + 1, g.shape[axis] - 1), axis=axis)
        g = 0.25 * lo + 0.5 * g + 0.25 * hi
    return g
