"""L1 Pallas kernel: zero-lag cross-correlation imaging condition.

``K += u_fwd * u_adj`` — the Frechet-kernel accumulator of AT step 3
(paper §4). Elementwise, so it tiles cleanly: the kernel demonstrates a
real HBM<->VMEM ``BlockSpec`` schedule by partitioning the mesh into
z-plane slabs (the leading axis), one grid step per slab. On TPU each
slab streams through VMEM; under ``interpret=True`` the same block
structure lowers to plain HLO for the CPU PJRT runtime.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _imaging_kernel(k_ref, fwd_ref, adj_ref, out_ref):
    out_ref[...] = k_ref[...] + fwd_ref[...] * adj_ref[...]


def _slab(nx: int) -> int:
    """Largest slab thickness <= 8 that divides the leading axis."""
    for cand in (8, 7, 6, 5, 4, 3, 2, 1):
        if nx % cand == 0:
            return cand
    return 1


def imaging_step(k_acc, u_fwd, u_adj):
    """Accumulate the imaging condition, tiled over leading-axis slabs.

    Semantically identical to :func:`ref.imaging_step`.
    """
    nx, ny, nz = k_acc.shape
    bx = _slab(nx)
    spec = pl.BlockSpec((bx, ny, nz), lambda i: (i, 0, 0))
    return pl.pallas_call(
        _imaging_kernel,
        grid=(nx // bx,),
        in_specs=[spec, spec, spec],
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct(k_acc.shape, k_acc.dtype),
        interpret=True,
    )(k_acc, u_fwd, u_adj)
