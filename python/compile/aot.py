"""AOT compiler: lower the L2 model to HLO-text artifacts for the Rust
runtime.

Interchange format is HLO **text**, not a serialized ``HloModuleProto``:
jax >= 0.5 emits protos with 64-bit instruction ids which the runtime's
XLA (xla_extension 0.5.1) rejects (``proto.id() <= INT_MAX``); the text
parser reassigns ids and round-trips cleanly. See
/opt/xla-example/README.md.

Outputs (under ``--outdir``, default ``../artifacts``):

* ``{step}_{mesh}.hlo.txt`` — one artifact per AT step per mesh
  (forward / misfit / frechet / update × demo / small / large).
* ``vecadd.hlo.txt`` — trivial artifact for runtime smoke tests.
* ``data/{mesh}_true_c.f32`` — the synthetic "true earth" velocity model
  (raw little-endian f32, C order); the coordinator simulates the
  observed data from it at workflow start.
* ``manifest.json`` — machine-readable index: mesh configs + per-artifact
  input/output signatures. The Rust runtime loads this instead of
  hard-coding shapes.

Usage: ``python -m compile.aot [--outdir DIR] [--meshes demo,small]``
"""

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (ids reassigned)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _sig(args):
    """JSON signature entry for a list of ShapeDtypeStructs."""
    return [["f32", list(a.shape)] for a in args]


def _spec(shape):
    return jax.ShapeDtypeStruct(shape, jnp.float32)


def lower_mesh(spec: model.MeshSpec, outdir: str, manifest: dict) -> None:
    """Lower the four AT steps for one mesh and register them."""
    field = _spec(spec.shape)
    scalar = _spec(())
    traces = _spec((spec.nt, spec.n_rec))
    chunk_rows = _spec((spec.chunk, spec.n_rec))

    steps = {
        f"forward_{spec.name}": (
            model.make_forward_chunk(spec),
            [field, field, field, scalar],
        ),
        f"misfit_{spec.name}": (model.make_misfit(spec), [traces, traces]),
        f"frechet_{spec.name}": (
            model.make_frechet_chunk(spec),
            [field, field, field, chunk_rows, field, field],
        ),
        f"update_{spec.name}": (
            model.make_model_update(spec),
            [field, field, scalar],
        ),
    }

    for name, (fn, args) in steps.items():
        path = os.path.join(outdir, f"{name}.hlo.txt")
        lowered = jax.jit(fn).lower(*args)
        out_avals = jax.tree_util.tree_leaves(lowered.out_info)
        text = to_hlo_text(lowered)
        with open(path, "w") as f:
            f.write(text)
        manifest["artifacts"][name] = {
            "file": os.path.basename(path),
            "inputs": _sig(args),
            "outputs": [["f32", list(o.shape)] for o in out_avals],
        }
        print(f"  {name}: {len(text) / 1024:.0f} KiB HLO")


def write_true_model(spec: model.MeshSpec, outdir: str) -> str:
    import numpy as np

    data_dir = os.path.join(outdir, "data")
    os.makedirs(data_dir, exist_ok=True)
    path = os.path.join(data_dir, f"{spec.name}_true_c.f32")
    arr = np.asarray(model.true_model(spec), dtype="<f4")
    arr.tofile(path)
    return os.path.join("data", os.path.basename(path))


def lower_vecadd(outdir: str, manifest: dict) -> None:
    def vecadd(x, y):
        return (x + y,)

    spec = _spec((8,))
    text = to_hlo_text(jax.jit(vecadd).lower(spec, spec))
    with open(os.path.join(outdir, "vecadd.hlo.txt"), "w") as f:
        f.write(text)
    manifest["artifacts"]["vecadd"] = {
        "file": "vecadd.hlo.txt",
        "inputs": [["f32", [8]], ["f32", [8]]],
        "outputs": [["f32", [8]]],
    }


def mesh_json(spec: model.MeshSpec) -> dict:
    return {
        "shape": list(spec.shape),
        "nt": spec.nt,
        "chunk": spec.chunk,
        "dt": spec.dt,
        "f0": spec.f0,
        "source": list(spec.source),
        "receivers": [list(r) for r in spec.receivers],
        "c_ref": spec.c_ref,
        "c_min": spec.c_min,
        "c_max": spec.c_max,
    }


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--outdir", default="../artifacts")
    ap.add_argument(
        "--meshes",
        default="demo,small,large",
        help="comma-separated subset of: " + ",".join(model.MESHES),
    )
    args = ap.parse_args()

    os.makedirs(args.outdir, exist_ok=True)
    manifest = {"version": 1, "meshes": {}, "artifacts": {}}

    lower_vecadd(args.outdir, manifest)
    for name in args.meshes.split(","):
        spec = model.MESHES[name]
        print(f"mesh {name} {spec.shape}:")
        lower_mesh(spec, args.outdir, manifest)
        entry = mesh_json(spec)
        entry["true_model_file"] = write_true_model(spec, args.outdir)
        manifest["meshes"][name] = entry

    with open(os.path.join(args.outdir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1, sort_keys=True)
    print(f"manifest: {len(manifest['artifacts'])} artifacts")


if __name__ == "__main__":
    main()
