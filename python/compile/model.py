"""L2: the Adjoint Tomography compute graph (paper §4) in JAX.

The paper's evaluation application has four computational steps:

  1. forward modelling  — synthetic seismograms from a velocity model
  2. misfit measurement — compare synthetic vs observed seismograms
  3. Frechet kernel     — adjoint simulation + imaging condition
  4. model update       — smoothed steepest-descent update

Each step is a jitted JAX function built on the L1 Pallas kernels
(``kernels.wave``, ``kernels.correlate``, ``kernels.smooth``) and is
AOT-lowered to an HLO-text artifact by ``aot.py``. The Rust coordinator
(Layer 3) drives the iteration loop, chunking time into ``chunk``-step
artifact calls, reversing the adjoint source in time, and line-searching
the update step — Python never runs at workflow-execution time.

Memory substitution (DESIGN.md §1): the paper's AT correlates the full
forward wavefield history with the adjoint field. Storing the history
for a 208x44x46 mesh is not feasible VMEM-resident, so the imaging
condition correlates per-chunk snapshots (a checkpointed
approximation); convergence is then guaranteed by the coordinator's
backtracking line search rather than by exact gradients. This preserves
the paper-relevant behaviour — step weights, data volumes and the
iterate/offload cadence — which is what the evaluation measures.
"""

from dataclasses import dataclass
from typing import Tuple

import jax
import jax.numpy as jnp

from .kernels import correlate, smooth, wave


@dataclass(frozen=True)
class MeshSpec:
    """Static configuration of one AT workload (one paper input mesh)."""

    name: str
    shape: Tuple[int, int, int]          # (nx, ny, nz) grid cells
    nt: int                              # total time steps per simulation
    chunk: int                           # time steps per artifact call
    dt: float                            # time-step size (dx = 1)
    f0: float                            # Ricker source peak frequency
    source: Tuple[int, int, int]         # source cell
    receivers: Tuple[Tuple[int, int, int], ...]  # receiver cells
    c_ref: float = 2.0                   # background velocity
    c_min: float = 1.2                   # clip floor for updates
    c_max: float = 3.5                   # clip ceiling for updates

    @property
    def n_chunks(self) -> int:
        assert self.nt % self.chunk == 0
        return self.nt // self.chunk

    @property
    def n_rec(self) -> int:
        return len(self.receivers)


def _receiver_line(shape, n_rec) -> Tuple[Tuple[int, int, int], ...]:
    """A line of receivers near the surface (z = 3), spread along x."""
    nx, ny, nz = shape
    xs = [int(round((i + 1) * nx / (n_rec + 1))) for i in range(n_rec)]
    return tuple((x, ny // 2, 3) for x in xs)


def _mesh(name, shape, nt, chunk) -> MeshSpec:
    nx, ny, nz = shape
    return MeshSpec(
        name=name,
        shape=shape,
        nt=nt,
        chunk=chunk,
        dt=0.15,
        f0=0.25,
        source=(nx // 2, ny // 2, nz // 2),
        receivers=_receiver_line(shape, 8),
    )


# The paper's two evaluation meshes (Figs 11 & 12) plus a tiny mesh for
# tests/quickstart. nt is scaled to this testbed (the paper does not
# report its step count); the chunk size is the unit of L3<->runtime
# interaction.
MESHES = {
    "demo": _mesh("demo", (24, 16, 16), 40, 8),
    "small": _mesh("small", (104, 23, 24), 240, 8),
    "large": _mesh("large", (208, 44, 46), 240, 8),
}


def ricker(t, f0):
    """Ricker wavelet with a 1/f0 onset delay."""
    ts = t - 1.0 / f0
    a = (jnp.pi * f0 * ts) ** 2
    return (1.0 - 2.0 * a) * jnp.exp(-a)


def _scatter_at(shape, cells, values, dtype):
    """Dense field that is ``values[i]`` at ``cells[i]`` and 0 elsewhere."""
    xs = jnp.array([c[0] for c in cells])
    ys = jnp.array([c[1] for c in cells])
    zs = jnp.array([c[2] for c in cells])
    return jnp.zeros(shape, dtype).at[xs, ys, zs].set(values)


def _gather_at(u, cells):
    xs = jnp.array([c[0] for c in cells])
    ys = jnp.array([c[1] for c in cells])
    zs = jnp.array([c[2] for c in cells])
    return u[xs, ys, zs]


# ----------------------------------------------------------------------
# AT step 1: forward modelling
# ----------------------------------------------------------------------

def make_forward_chunk(spec: MeshSpec):
    """Build ``forward_chunk(u, u_prev, c, k0) -> (u, u_prev, seis)``.

    Advances the acoustic wavefield ``spec.chunk`` leap-frog steps from
    global step index ``k0`` (a traced scalar so one artifact serves the
    whole simulation), injecting the Ricker source and recording the
    receiver line. ``seis`` has shape ``(chunk, n_rec)``.
    """

    def forward_chunk(u, u_prev, c, k0):
        c2dt2 = (c * spec.dt) ** 2

        def body(carry, i):
            u, um = carry
            amp = ricker((k0 + i.astype(u.dtype)) * spec.dt, spec.f0)
            src = _scatter_at(spec.shape, (spec.source,), amp[None], u.dtype)
            un = wave.wave_step(u, um, c2dt2, src)
            return (un, u), _gather_at(un, spec.receivers)

        (u, um), seis = jax.lax.scan(body, (u, u_prev), jnp.arange(spec.chunk))
        return u, um, seis

    return forward_chunk


# ----------------------------------------------------------------------
# AT step 2: misfit measurement
# ----------------------------------------------------------------------

def make_misfit(spec: MeshSpec):
    """Build ``misfit(syn, obs) -> (misfit, adj_src)``.

    L2 waveform misfit over the full traces ``(nt, n_rec)`` plus the
    adjoint source (the residual; the coordinator time-reverses it
    before the adjoint simulation).
    """

    def misfit(syn, obs):
        r = syn - obs
        return 0.5 * jnp.sum(r * r), r

    return misfit


# ----------------------------------------------------------------------
# AT step 3: Frechet kernel (adjoint simulation + imaging condition)
# ----------------------------------------------------------------------

def make_frechet_chunk(spec: MeshSpec):
    """Build ``frechet_chunk(a, a_prev, c, adj_chunk, u_snap, k_acc)``.

    Advances the adjoint wavefield ``spec.chunk`` steps, injecting the
    (time-reversed) residual at the receiver line, then accumulates the
    zero-lag imaging condition against the forward-field snapshot of the
    matching chunk. Returns ``(a, a_prev, k_acc)``.
    """

    def frechet_chunk(a, a_prev, c, adj_chunk, u_snap, k_acc):
        c2dt2 = (c * spec.dt) ** 2

        def body(carry, adj_row):
            a, am = carry
            src = _scatter_at(spec.shape, spec.receivers, adj_row, a.dtype)
            an = wave.wave_step(a, am, c2dt2, src)
            return (an, a), jnp.float32(0.0)

        (a, am), _ = jax.lax.scan(body, (a, a_prev), adj_chunk)
        k_acc = correlate.imaging_step(k_acc, u_snap, a)
        return a, am, k_acc

    return frechet_chunk


# ----------------------------------------------------------------------
# AT step 4: model update
# ----------------------------------------------------------------------

def make_model_update(spec: MeshSpec):
    """Build ``model_update(c, k, alpha) -> c_new``.

    Smooths the Frechet kernel, normalizes it to unit max-amplitude, and
    takes a clipped steepest-descent step of (signed) length ``alpha``.
    The coordinator line-searches ``alpha``.
    """

    def model_update(c, k, alpha):
        g = smooth.smooth3(k)
        g = g / (jnp.max(jnp.abs(g)) + 1e-12)
        return jnp.clip(c - alpha * g, spec.c_min, spec.c_max)

    return model_update


# ----------------------------------------------------------------------
# Synthetic ground truth (generates the "observed data" for a mesh)
# ----------------------------------------------------------------------

def true_model(spec: MeshSpec):
    """The unknown earth model: background velocity plus a Gaussian
    high-velocity anomaly off-centre (what AT tries to recover)."""
    nx, ny, nz = spec.shape
    x, y, z = jnp.meshgrid(
        jnp.arange(nx), jnp.arange(ny), jnp.arange(nz), indexing="ij"
    )
    cx, cy, cz = nx * 0.5, ny * 0.5, nz * 0.35
    r2 = (x - cx) ** 2 + (y - cy) ** 2 + (z - cz) ** 2
    sigma = max(2.0, min(nx, ny, nz) / 6.0)
    return (spec.c_ref + 0.5 * jnp.exp(-r2 / (2 * sigma**2))).astype(
        jnp.float32
    )


def starting_model(spec: MeshSpec):
    """The initial guess: homogeneous background."""
    return jnp.full(spec.shape, spec.c_ref, jnp.float32)


# ----------------------------------------------------------------------
# Pure-Python driver (reference implementation of the L3 loop; tests use
# it to validate the artifact contract end-to-end)
# ----------------------------------------------------------------------

def run_forward(spec: MeshSpec, c):
    """Full forward simulation: returns (seis [nt, n_rec], snapshots)."""
    fwd = jax.jit(make_forward_chunk(spec))
    u = jnp.zeros(spec.shape, jnp.float32)
    um = jnp.zeros(spec.shape, jnp.float32)
    rows, snaps = [], []
    for ci in range(spec.n_chunks):
        u, um, seis = fwd(u, um, c, jnp.float32(ci * spec.chunk))
        rows.append(seis)
        snaps.append(u)
    return jnp.concatenate(rows, 0), snaps


def run_frechet(spec: MeshSpec, c, adj, snaps):
    """Full adjoint simulation: returns the Frechet kernel K."""
    fre = jax.jit(make_frechet_chunk(spec))
    a = jnp.zeros(spec.shape, jnp.float32)
    am = jnp.zeros(spec.shape, jnp.float32)
    k = jnp.zeros(spec.shape, jnp.float32)
    adj_rev = adj[::-1]  # time-reversed residual
    for ci in range(spec.n_chunks):
        rows = adj_rev[ci * spec.chunk : (ci + 1) * spec.chunk]
        # chunk ci of the reversed adjoint pairs with forward chunk
        # n_chunks-1-ci (zero lag in the checkpointed approximation)
        u_snap = snaps[spec.n_chunks - 1 - ci]
        a, am, k = fre(a, am, c, rows, u_snap, k)
    return k
