# pytest: L2 model-level checks — shapes, physics sanity, and an
# end-to-end mini-inversion on the demo mesh (the reference
# implementation of the contract the Rust coordinator drives).
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model

jax.config.update("jax_platform_name", "cpu")

SPEC = model.MESHES["demo"]


@pytest.fixture(scope="module")
def observed():
    seis, _ = model.run_forward(SPEC, model.true_model(SPEC))
    return seis


class TestSpecs:
    def test_mesh_registry_has_paper_meshes(self):
        assert model.MESHES["small"].shape == (104, 23, 24)
        assert model.MESHES["large"].shape == (208, 44, 46)

    def test_chunking_divides_nt(self):
        for spec in model.MESHES.values():
            assert spec.nt % spec.chunk == 0

    def test_receivers_inside_mesh(self):
        for spec in model.MESHES.values():
            for r in spec.receivers:
                assert all(0 <= r[d] < spec.shape[d] for d in range(3))


class TestForward:
    def test_chunk_shapes(self):
        fwd = model.make_forward_chunk(SPEC)
        z = jnp.zeros(SPEC.shape, jnp.float32)
        c = model.starting_model(SPEC)
        u, um, seis = fwd(z, z, c, jnp.float32(0.0))
        assert u.shape == SPEC.shape
        assert um.shape == SPEC.shape
        assert seis.shape == (SPEC.chunk, SPEC.n_rec)

    def test_wave_reaches_receivers(self, observed):
        # The source must actually arrive: traces are non-trivial.
        assert float(jnp.abs(observed).max()) > 1e-4

    def test_k0_continuation_consistent(self):
        # Running 2 chunks via the chunk interface == running them as
        # one longer simulation (the carry contract Rust relies on).
        c = model.true_model(SPEC)
        seis, _ = model.run_forward(SPEC, c)
        fwd = jax.jit(model.make_forward_chunk(SPEC))
        z = jnp.zeros(SPEC.shape, jnp.float32)
        u, um = z, z
        rows = []
        for ci in range(SPEC.n_chunks):
            u, um, s = fwd(u, um, c, jnp.float32(ci * SPEC.chunk))
            rows.append(s)
        np.testing.assert_allclose(jnp.concatenate(rows, 0), seis, atol=1e-6)

    def test_field_stays_bounded(self):
        c = model.true_model(SPEC)
        _, snaps = model.run_forward(SPEC, c)
        assert float(jnp.abs(snaps[-1]).max()) < 100.0


class TestMisfit:
    def test_zero_for_identical(self, observed):
        mis = model.make_misfit(SPEC)
        m, adj = mis(observed, observed)
        assert float(m) == 0.0
        assert float(jnp.abs(adj).max()) == 0.0

    def test_positive_for_different(self, observed):
        mis = model.make_misfit(SPEC)
        syn, _ = model.run_forward(SPEC, model.starting_model(SPEC))
        m, adj = mis(syn, observed)
        assert float(m) > 0.0
        np.testing.assert_allclose(adj, syn - observed)


class TestFrechet:
    def test_kernel_nonzero_and_finite(self, observed):
        c0 = model.starting_model(SPEC)
        syn, snaps = model.run_forward(SPEC, c0)
        _, adj = model.make_misfit(SPEC)(syn, observed)
        k = model.run_frechet(SPEC, c0, adj, snaps)
        assert k.shape == SPEC.shape
        assert bool(jnp.isfinite(k).all())
        assert float(jnp.abs(k).max()) > 0.0

    def test_zero_residual_gives_zero_kernel(self, observed):
        c = model.true_model(SPEC)
        _, snaps = model.run_forward(SPEC, c)
        adj = jnp.zeros((SPEC.nt, SPEC.n_rec), jnp.float32)
        k = model.run_frechet(SPEC, c, adj, snaps)
        assert float(jnp.abs(k).max()) == 0.0


class TestUpdate:
    def test_respects_clip_bounds(self):
        upd = model.make_model_update(SPEC)
        c = model.starting_model(SPEC)
        k = jnp.ones(SPEC.shape, jnp.float32)
        c2 = upd(c, k, jnp.float32(100.0))
        assert float(c2.min()) >= SPEC.c_min - 1e-6
        assert float(c2.max()) <= SPEC.c_max + 1e-6

    def test_zero_alpha_is_identity(self):
        upd = model.make_model_update(SPEC)
        c = model.true_model(SPEC)
        k = jnp.ones(SPEC.shape, jnp.float32)
        np.testing.assert_allclose(upd(c, k, jnp.float32(0.0)), c, atol=1e-6)


class TestInversionLoop:
    def test_line_searched_iteration_decreases_misfit(self, observed):
        # Reference implementation of the L3 loop: one AT iteration with
        # a signed backtracking line search must reduce the misfit.
        mis = model.make_misfit(SPEC)
        upd = jax.jit(model.make_model_update(SPEC))
        c = model.starting_model(SPEC)

        syn, snaps = model.run_forward(SPEC, c)
        m0, adj = mis(syn, observed)
        k = model.run_frechet(SPEC, c, adj, snaps)

        best = float(m0)
        best_c = c
        for alpha in (0.2, -0.2, 0.1, -0.1, 0.05, -0.05):
            c_try = upd(c, k, jnp.float32(alpha))
            syn_try, _ = model.run_forward(SPEC, c_try)
            m_try, _ = mis(syn_try, observed)
            if float(m_try) < best:
                best, best_c = float(m_try), c_try
                break
        assert best < float(m0), "no trial step reduced the misfit"
