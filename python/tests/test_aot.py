# pytest: the AOT pipeline — HLO-text emission and the manifest
# contract the Rust runtime depends on.
import json
import os

import jax
import jax.numpy as jnp
import pytest

from compile import aot, model

jax.config.update("jax_platform_name", "cpu")


@pytest.fixture(scope="module")
def demo_artifacts(tmp_path_factory):
    outdir = tmp_path_factory.mktemp("artifacts")
    manifest = {"version": 1, "meshes": {}, "artifacts": {}}
    aot.lower_vecadd(str(outdir), manifest)
    spec = model.MESHES["demo"]
    aot.lower_mesh(spec, str(outdir), manifest)
    entry = aot.mesh_json(spec)
    entry["true_model_file"] = aot.write_true_model(spec, str(outdir))
    manifest["meshes"]["demo"] = entry
    with open(outdir / "manifest.json", "w") as f:
        json.dump(manifest, f)
    return outdir, manifest


class TestHloText:
    def test_artifacts_are_hlo_text(self, demo_artifacts):
        outdir, manifest = demo_artifacts
        for name, spec in manifest["artifacts"].items():
            text = (outdir / spec["file"]).read_text()
            # HLO text (parseable by the runtime's text parser), not a
            # serialized proto: must declare an entry computation.
            assert "HloModule" in text, f"{name} is not HLO text"
            assert "ENTRY" in text, f"{name} missing entry computation"

    def test_signatures_match_lowering(self, demo_artifacts):
        _, manifest = demo_artifacts
        spec = model.MESHES["demo"]
        fwd = manifest["artifacts"]["forward_demo"]
        shape = list(spec.shape)
        assert fwd["inputs"] == [
            ["f32", shape], ["f32", shape], ["f32", shape], ["f32", []]
        ]
        assert fwd["outputs"] == [
            ["f32", shape], ["f32", shape], ["f32", [spec.chunk, spec.n_rec]]
        ]
        mis = manifest["artifacts"]["misfit_demo"]
        assert mis["outputs"][0] == ["f32", []]

    def test_true_model_file_shape(self, demo_artifacts):
        import numpy as np

        outdir, manifest = demo_artifacts
        spec = model.MESHES["demo"]
        path = outdir / manifest["meshes"]["demo"]["true_model_file"]
        arr = np.fromfile(path, dtype="<f4")
        assert arr.size == spec.shape[0] * spec.shape[1] * spec.shape[2]
        assert arr.min() >= spec.c_ref - 1e-6
        assert arr.max() <= spec.c_ref + 0.5 + 1e-6

    def test_mesh_json_complete(self):
        entry = aot.mesh_json(model.MESHES["small"])
        for key in ("shape", "nt", "chunk", "dt", "f0", "source",
                    "receivers", "c_ref", "c_min", "c_max"):
            assert key in entry, key
        assert entry["shape"] == [104, 23, 24]


class TestDeterminism:
    def test_lowering_is_deterministic(self):
        spec = model.MESHES["demo"]
        fn = model.make_misfit(spec)
        traces = jax.ShapeDtypeStruct((spec.nt, spec.n_rec), jnp.float32)
        a = aot.to_hlo_text(jax.jit(fn).lower(traces, traces))
        b = aot.to_hlo_text(jax.jit(fn).lower(traces, traces))
        assert a == b
