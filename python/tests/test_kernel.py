# pytest: Pallas kernels vs pure-jnp oracles — the CORE correctness
# signal for Layer 1. hypothesis sweeps shapes and seeds.
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import correlate, ref, smooth, wave

jax.config.update("jax_platform_name", "cpu")


def rand_field(shape, seed, scale=1.0):
    rng = np.random.RandomState(seed)
    return jnp.asarray(rng.randn(*shape).astype(np.float32) * scale)


dims = st.integers(min_value=5, max_value=24)
shapes = st.tuples(dims, dims, dims)
seeds = st.integers(min_value=0, max_value=2**31 - 1)


class TestWaveStep:
    @settings(max_examples=20, deadline=None)
    @given(shape=shapes, seed=seeds)
    def test_matches_ref(self, shape, seed):
        u = rand_field(shape, seed)
        um = rand_field(shape, seed + 1)
        c2 = rand_field(shape, seed + 2, 0.05) ** 2
        src = rand_field(shape, seed + 3, 0.1)
        got = wave.wave_step(u, um, c2, src)
        want = ref.wave_step(u, um, c2, src)
        np.testing.assert_allclose(got, want, rtol=1e-6, atol=1e-6)

    def test_zero_field_stays_zero(self):
        z = jnp.zeros((8, 8, 8), jnp.float32)
        c2 = jnp.full((8, 8, 8), 0.1, jnp.float32)
        out = wave.wave_step(z, z, c2, z)
        assert float(jnp.abs(out).max()) == 0.0

    def test_source_injection_additive(self):
        z = jnp.zeros((8, 8, 8), jnp.float32)
        c2 = jnp.full((8, 8, 8), 0.1, jnp.float32)
        src = z.at[4, 4, 4].set(1.5)
        out = wave.wave_step(z, z, c2, src)
        np.testing.assert_allclose(out, src, atol=0)

    def test_boundary_shell_has_no_laplacian(self):
        # On the 2-cell boundary shell the update must reduce to
        # 2u - u_prev + src (zero-Dirichlet Laplacian).
        u = rand_field((9, 9, 9), 7)
        um = rand_field((9, 9, 9), 8)
        c2 = jnp.full((9, 9, 9), 0.2, jnp.float32)
        out = wave.wave_step(u, um, c2, jnp.zeros_like(u))
        expect = 2.0 * u - um
        np.testing.assert_allclose(out[0], expect[0], rtol=1e-6)
        np.testing.assert_allclose(out[:, 1], expect[:, 1], rtol=1e-6)
        np.testing.assert_allclose(out[..., -2], expect[..., -2], rtol=1e-6)

    def test_energy_bounded_under_cfl(self):
        # A stable scheme must not blow up over 100 steps.
        u = jnp.zeros((16, 16, 16), jnp.float32).at[8, 8, 8].set(1.0)
        um = u
        c2 = jnp.full((16, 16, 16), 0.09, jnp.float32)  # courant 0.3
        z = jnp.zeros_like(u)
        for _ in range(100):
            u, um = wave.wave_step(u, um, c2, z), u
        assert float(jnp.abs(u).max()) < 10.0


class TestImagingStep:
    @settings(max_examples=20, deadline=None)
    @given(shape=shapes, seed=seeds)
    def test_matches_ref(self, shape, seed):
        k = rand_field(shape, seed)
        f = rand_field(shape, seed + 1)
        a = rand_field(shape, seed + 2)
        got = correlate.imaging_step(k, f, a)
        np.testing.assert_allclose(
            got, ref.imaging_step(k, f, a), rtol=1e-6, atol=1e-6
        )

    def test_accumulates(self):
        k = jnp.zeros((8, 8, 8), jnp.float32)
        f = jnp.ones((8, 8, 8), jnp.float32)
        a = jnp.full((8, 8, 8), 2.0, jnp.float32)
        k = correlate.imaging_step(k, f, a)
        k = correlate.imaging_step(k, f, a)
        np.testing.assert_allclose(k, jnp.full_like(k, 4.0))

    def test_slab_tiling_covers_odd_sizes(self):
        # 13 is prime: the BlockSpec tiling must fall back to slab=1 and
        # still produce the right answer on every plane.
        k = rand_field((13, 6, 7), 3)
        f = rand_field((13, 6, 7), 4)
        a = rand_field((13, 6, 7), 5)
        np.testing.assert_allclose(
            correlate.imaging_step(k, f, a),
            ref.imaging_step(k, f, a),
            rtol=1e-6,
            atol=1e-6,
        )


class TestSmooth3:
    @settings(max_examples=20, deadline=None)
    @given(shape=shapes, seed=seeds)
    def test_matches_ref(self, shape, seed):
        g = rand_field(shape, seed)
        np.testing.assert_allclose(
            smooth.smooth3(g), ref.smooth3(g), rtol=1e-5, atol=1e-6
        )

    def test_preserves_constants(self):
        g = jnp.full((10, 9, 8), 3.25, jnp.float32)
        np.testing.assert_allclose(smooth.smooth3(g), g, rtol=1e-6)

    def test_reduces_total_variation(self):
        g = rand_field((12, 12, 12), 11)
        s = smooth.smooth3(g)
        tv = lambda x: float(jnp.abs(jnp.diff(x, axis=0)).sum())
        assert tv(s) < tv(g)


class TestLaplacianRef:
    def test_quadratic_has_constant_laplacian(self):
        # u = x^2 -> d2u/dx2 = 2 exactly under a 4th-order stencil.
        n = 12
        x = jnp.arange(n, dtype=jnp.float32)
        u = jnp.broadcast_to(x[:, None, None] ** 2, (n, n, n))
        lap = ref.laplacian4(u)
        np.testing.assert_allclose(
            lap[2:-2, 2:-2, 2:-2], 2.0, rtol=1e-4, atol=1e-4
        )

    def test_boundary_shell_zero(self):
        u = rand_field((10, 10, 10), 2)
        lap = ref.laplacian4(u)
        assert float(jnp.abs(lap[:2]).max()) == 0.0
        assert float(jnp.abs(lap[:, :2]).max()) == 0.0
        assert float(jnp.abs(lap[..., -2:]).max()) == 0.0
